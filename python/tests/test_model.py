"""L2 model invariants: shapes, causality, trainability, architecture variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, optimizers

CFG = configs.SIZES["s60m"]


def _batch(cfg, b=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, cfg.seq_len + 1)).astype(np.int32))


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 0)


def test_param_specs_match_init(params):
    specs = model.param_specs(CFG)
    assert len(specs) == len(params)
    for (name, kind, shape), p in zip(specs, params):
        assert p.shape == tuple(shape), name
        assert p.dtype == jnp.float32, name


def test_param_count_formula():
    for cfg in configs.SIZES.values():
        params = model.init_params(cfg, 0)
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == cfg.param_count(), cfg.name


def test_init_deterministic_and_seed_sensitive():
    a = model.init_params(CFG, 42)
    b = model.init_params(CFG, 42)
    c = model.init_params(CFG, 43)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert any(not np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, c))


def test_forward_shapes(params):
    tok = _batch(CFG)[:, :-1]
    logits = model.forward(CFG, params, tok)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_initial_loss_near_uniform(params):
    """Fresh model ≈ uniform predictor: loss ≈ log |V|."""
    loss = model.loss_fn(CFG, params, _batch(CFG, b=4))
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_causality(params):
    """Perturbing future tokens must not change earlier logits."""
    tok = np.asarray(_batch(CFG))[:, :-1].copy()
    logits_a = np.asarray(model.forward(CFG, params, jnp.asarray(tok)))
    tok_b = tok.copy()
    tok_b[:, -1] = (tok_b[:, -1] + 1) % CFG.vocab
    logits_b = np.asarray(model.forward(CFG, params, jnp.asarray(tok_b)))
    np.testing.assert_allclose(logits_a[:, :-1], logits_b[:, :-1], atol=1e-5)
    assert not np.allclose(logits_a[:, -1], logits_b[:, -1])


def test_grads_cover_all_params(params):
    out = model.fwd_bwd(CFG, params, _batch(CFG, b=4))
    grads = out[1:]
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert float(jnp.sum(jnp.abs(g))) > 0.0  # every param receives signal


def test_fwd_bwd_loss_matches_eval(params):
    b = _batch(CFG, b=4)
    loss_fb = model.fwd_bwd(CFG, params, b)[0]
    loss_ev = model.eval_step(CFG, params, b)
    np.testing.assert_allclose(float(loss_fb), float(loss_ev), rtol=1e-6)


def test_few_steps_of_scale_reduce_loss(params):
    """Integration: Algorithm 1 actually trains the model (structured data)."""
    cfg = CFG
    opt = optimizers.REGISTRY["scale"]
    rng = np.random.default_rng(0)
    # a learnable distribution: token t+1 = (t + 1) mod 64
    start = rng.integers(0, 64, size=(8, 1))
    seq = (start + np.arange(cfg.seq_len + 1)) % 64
    batch = jnp.asarray(seq.astype(np.int32))
    ps = list(params)
    st = opt.init_state(cfg)
    losses = []
    for step in range(1, 16):
        out = model.fwd_bwd(cfg, ps, batch)
        losses.append(float(out[0]))
        ps, st = opt.update(cfg, ps, st, list(out[1:]), jnp.float32(3e-3), jnp.float32(step))
    assert losses[-1] < losses[0] - 0.5, losses


def test_gpt2_variant_runs():
    cfg = configs.SIZES["gpt2s"]
    params = model.init_params(cfg, 0)
    out = model.fwd_bwd(cfg, params, _batch(cfg, b=2))
    assert np.isfinite(float(out[0]))
    names = [n for n, _, _ in model.param_specs(cfg)]
    assert "pos_embed" in names and "block0.w_gate" not in names


def test_variance_probe_shapes(params):
    small = _batch(CFG, b=4, seed=1)
    big = _batch(CFG, b=16, seed=2)
    out = model.grad_variance_probe(CFG, params, small, big)
    assert len(out) == len(params)
    assert all(float(v) >= 0 for v in out)


def _zipf_batch(cfg, b, seed):
    """Zipf-distributed tokens — the skewed frequency regime (App. M) in
    which the paper measures per-layer variance (Fig. 4)."""
    rng = np.random.default_rng(seed)
    tok = rng.zipf(1.3, size=(b, cfg.seq_len + 1)) - 1
    return jnp.asarray(np.minimum(tok, cfg.vocab - 1).astype(np.int32))


def test_lm_head_variance_is_large(params):
    """Fig. 4 premise: after a little training on skewed data, the LM head's
    total gradient variance dominates the hidden layers'."""
    cfg = CFG
    opt = optimizers.REGISTRY["sgd_colnorm"]
    ps, st = list(params), opt.init_state(cfg)
    for step in range(1, 21):
        out = model.fwd_bwd(cfg, ps, _zipf_batch(cfg, 4, step))
        ps, st = opt.update(cfg, ps, st, list(out[1:]), jnp.float32(1e-3), jnp.float32(step))
    small = _zipf_batch(cfg, 4, 1003)
    big = _zipf_batch(cfg, 16, 1004)
    out = model.grad_variance_probe(cfg, ps, small, big)
    specs = model.param_specs(cfg)
    totals = {n: float(v) * int(np.prod(s)) for (n, _, s), v in zip(specs, out)}
    head = totals["lm_head"]
    hidden = [v for n, v in totals.items() if n.startswith("block") and "norm" not in n]
    assert head > np.median(hidden), totals
