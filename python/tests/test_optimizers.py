"""L2 optimizer-zoo semantics tests.

Checks each optimizer's update against hand-written numpy math for small
shapes, plus the structural invariants the paper's design depends on:
SCALE keeps momentum ONLY for the LM head; GaLore/Fira/APOLLO/SWAN use
full Adam on first/last layers; state layouts match their manifests.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, optimizers
from compile.kernels import ref

CFG = configs.SIZES["s60m"]
SPECS = model.param_specs(CFG)


def _rand_like(shapes, seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(scale * rng.normal(size=s).astype(np.float32)) for s in shapes]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 7)


@pytest.fixture(scope="module")
def grads():
    return _rand_like([s for _, _, s in SPECS], seed=1)


# --------------------------------------------------------------------------
# Structural invariants
# --------------------------------------------------------------------------

def test_registry_complete():
    expected = set(optimizers.CORE_SET + optimizers.NORM_SET + optimizers.ABLATION_SET)
    expected.add("ns_mmt_last")
    assert expected <= set(optimizers.REGISTRY)


def test_scale_state_is_head_momentum_plus_vector_adam():
    st = optimizers.REGISTRY["scale"].state_specs(CFG)
    names = [n for n, _ in st]
    # exactly one momentum matrix: the LM head
    mats = [n for n in names if n.endswith(".m") and n.startswith("lm_head")]
    assert mats == ["lm_head.m"]
    # nothing for embed or hidden matrices
    assert not any(n.startswith(("embed.", "block")) and not n.endswith((".m", ".v"))
                   for n in names)
    for n, _, _ in SPECS:
        pass
    hidden_states = [n for n in names
                     if n.split(".")[0].startswith("block") and ".w" in n]
    assert hidden_states == []


def test_scale_memory_is_sgd_like():
    """SCALE state elems ≈ head + vectors only — the paper's memory claim."""
    total_params = sum(int(np.prod(s)) for _, _, s in SPECS)
    st = optimizers.REGISTRY["scale"].state_specs(CFG)
    st_elems = sum(int(np.prod(s)) for _, s in st)
    adam_elems = sum(
        int(np.prod(s)) for _, s in optimizers.REGISTRY["adam"].state_specs(CFG)
    )
    assert adam_elems == 2 * total_params
    # far below Adam; head dominates (vvocab*d) for tiny models
    assert st_elems < 0.5 * adam_elems


@pytest.mark.parametrize("name", ["galore", "fira", "apollo", "apollo_mini", "swan", "muon"])
def test_first_last_layer_full_adam(name):
    st_names = [n for n, _ in optimizers.REGISTRY[name].state_specs(CFG)]
    assert "embed.m" in st_names and "embed.v" in st_names
    assert "lm_head.m" in st_names and "lm_head.v" in st_names


def test_galore_states_are_low_rank():
    for n, s in optimizers.REGISTRY["galore"].state_specs(CFG):
        # hidden weight-matrix momenta only (vector params carry Adam)
        if n.startswith("block") and ".w" in n and n.endswith(".m"):
            d_in, d_out = s
            assert d_in <= 12  # rank << min dim


def test_state_update_preserves_layout(params, grads):
    for name, opt in optimizers.REGISTRY.items():
        st = opt.init_state(CFG)
        pn, sn = opt.update(CFG, params, st, grads, jnp.float32(1e-3), jnp.float32(1.0))
        assert len(pn) == len(params), name
        assert len(sn) == len(st), name
        for a, b in zip(sn, st):
            assert a.shape == b.shape, name
        for a, b in zip(pn, params):
            assert a.shape == b.shape, name
            assert np.all(np.isfinite(np.asarray(a))), name


# --------------------------------------------------------------------------
# Numeric semantics vs hand math
# --------------------------------------------------------------------------

def _param_index(name):
    return [i for i, (n, _, _) in enumerate(SPECS) if n == name][0]


def test_sgd_is_plain_descent(params, grads):
    opt = optimizers.REGISTRY["sgd"]
    pn, _ = opt.update(CFG, params, [], grads, jnp.float32(0.5), jnp.float32(1.0))
    for p, g, p2 in zip(params, grads, pn):
        np.testing.assert_allclose(p2, p - 0.5 * g, atol=1e-6)


def test_scale_matches_algorithm1(params, grads):
    """Hidden matrices: p -= lr*C(g). Head: EMA then p -= lr*C(m)."""
    opt = optimizers.REGISTRY["scale"]
    st = opt.init_state(CFG)
    lr, beta = 0.01, optimizers.BETA
    pn, sn = opt.update(CFG, params, st, grads, jnp.float32(lr), jnp.float32(1.0))

    i = _param_index("block0.wq")
    expect = params[i] - lr * ref.colnorm_ref(grads[i])
    np.testing.assert_allclose(pn[i], expect, atol=1e-5)

    h = _param_index("lm_head")
    m1 = (1 - beta) * grads[h]
    expect_head = params[h] - lr * ref.colnorm_ref(m1)
    np.testing.assert_allclose(pn[h], expect_head, atol=1e-5)

    # second step uses the carried momentum
    pn2, sn2 = opt.update(CFG, pn, sn, grads, jnp.float32(lr), jnp.float32(2.0))
    m2 = beta * m1 + (1 - beta) * grads[h]
    st_names = [n for n, _ in opt.state_specs(CFG)]
    np.testing.assert_allclose(
        sn2[st_names.index("lm_head.m")], m2, atol=1e-5
    )


def test_adam_matches_ref_everywhere(params, grads):
    opt = optimizers.REGISTRY["adam"]
    st = opt.init_state(CFG)
    pn, _ = opt.update(CFG, params, st, grads, jnp.float32(1e-3), jnp.float32(1.0))
    i = _param_index("block0.wv")
    expect, _, _ = ref.adam_update_ref(
        params[i], jnp.zeros_like(params[i]), jnp.zeros_like(params[i]),
        grads[i], 1e-3, optimizers.ADAM_B1, optimizers.ADAM_B2,
        optimizers.ADAM_EPS, 1.0)
    np.testing.assert_allclose(pn[i], expect, atol=1e-6)


def test_sign_sgd(params, grads):
    opt = optimizers.REGISTRY["sign_sgd"]
    st = opt.init_state(CFG)
    pn, _ = opt.update(CFG, params, st, grads, jnp.float32(0.01), jnp.float32(1.0))
    i = _param_index("block1.wo")
    np.testing.assert_allclose(pn[i], params[i] - 0.01 * jnp.sign(grads[i]), atol=1e-6)


def test_muon_direction_is_orthogonalized(params, grads):
    """After one Muon step the hidden update direction ~ orthogonal matrix."""
    opt = optimizers.REGISTRY["muon"]
    st = opt.init_state(CFG)
    pn, _ = opt.update(CFG, params, st, grads, jnp.float32(1.0), jnp.float32(1.0))
    i = _param_index("block0.wq")
    scale = 0.2 * np.sqrt(max(params[i].shape))
    d = np.asarray((params[i] - pn[i])) / scale  # lr=1
    gram = d.T @ d
    # NS(5) gives approximately orthonormal columns (singular values ~1)
    sv = np.linalg.svd(gram, compute_uv=False)
    assert 0.5 < np.median(sv) < 1.5


def test_stable_spam_reset_zeroes_momentum(params, grads):
    opt = optimizers.REGISTRY["stable_spam"]
    st = opt.init_state(CFG)
    # warm up one step, then hit the reset step
    _, st1 = opt.update(CFG, params, st, grads, jnp.float32(1e-3), jnp.float32(1.0))
    reset_step = float(optimizers.SPAM_RESET)
    _, st2 = opt.update(CFG, params, st1, grads, jnp.float32(1e-3), jnp.float32(reset_step))
    names = [n for n, _ in opt.state_specs(CFG)]
    m_idx = names.index("block0.wq.m")
    beta1 = optimizers.ADAM_B1
    # after reset, m == (1-beta1) * g_clipped exactly (previous m erased)
    m_new = np.asarray(st2[m_idx])
    g = np.asarray(grads[_param_index("block0.wq")])
    # gradient was not clipped in this regime (gmax grew past |g|)
    np.testing.assert_allclose(m_new, (1 - beta1) * g, rtol=1e-4, atol=1e-6)


def test_apollo_mini_scales_raw_gradient(params, grads):
    """APOLLO-Mini's direction is s * g — colinear with the gradient."""
    opt = optimizers.REGISTRY["apollo_mini"]
    st = opt.init_state(CFG)
    pn, _ = opt.update(CFG, params, st, grads, jnp.float32(1e-3), jnp.float32(1.0))
    i = _param_index("block0.w_up")
    d = np.asarray(params[i] - pn[i]).ravel()
    g = np.asarray(grads[i]).ravel()
    cos = d @ g / (np.linalg.norm(d) * np.linalg.norm(g) + 1e-12)
    np.testing.assert_allclose(cos, 1.0, atol=1e-5)


def test_update_is_deterministic(params, grads):
    opt = optimizers.REGISTRY["galore"]
    st = opt.init_state(CFG)
    a, _ = opt.update(CFG, params, st, grads, jnp.float32(1e-3), jnp.float32(1.0))
    b, _ = opt.update(CFG, params, st, grads, jnp.float32(1e-3), jnp.float32(1.0))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
