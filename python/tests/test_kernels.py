"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes (prime/odd dims exercise the tile-divisor
search) and value regimes (tiny, huge, zero columns); the oracles are
the spec, so any mismatch is a kernel bug by definition.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    adam_update,
    colnorm,
    rownorm,
    scale_update_momentum,
    scale_update_plain,
    sign,
)
from compile.kernels import ref
from compile.kernels.colnorm import _pick_tile

DIMS = st.integers(min_value=1, max_value=97)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _mat(seed, d_in, d_out, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(scale * rng.normal(size=(d_in, d_out)).astype(np.float32))


# --------------------------------------------------------------------------
# Normalization kernels
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(DIMS, DIMS, SEEDS)
def test_colnorm_matches_ref(d_in, d_out, seed):
    g = _mat(seed, d_in, d_out)
    np.testing.assert_allclose(colnorm(g), ref.colnorm_ref(g), atol=1e-5, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(DIMS, DIMS, SEEDS)
def test_rownorm_matches_ref(d_in, d_out, seed):
    g = _mat(seed, d_in, d_out)
    np.testing.assert_allclose(rownorm(g), ref.rownorm_ref(g), atol=1e-5, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(DIMS, DIMS, SEEDS)
def test_sign_matches_ref(d_in, d_out, seed):
    g = _mat(seed, d_in, d_out)
    np.testing.assert_array_equal(sign(g), ref.sign_ref(g))


@settings(max_examples=20, deadline=None)
@given(DIMS, DIMS, SEEDS)
def test_colnorm_unit_columns(d_in, d_out, seed):
    """Every nonzero column of C(G) has unit L2 norm — the paper's invariant."""
    g = _mat(seed, d_in, d_out)
    out = np.asarray(colnorm(g))
    norms = np.linalg.norm(out, axis=0)
    np.testing.assert_allclose(norms, np.ones_like(norms), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(DIMS, DIMS, SEEDS, st.floats(min_value=0.01, max_value=100.0))
def test_colnorm_scale_invariant(d_in, d_out, seed, alpha):
    """C(alpha * G) == C(G) for alpha > 0 — normalization kills magnitude."""
    g = _mat(seed, d_in, d_out)
    np.testing.assert_allclose(
        colnorm(jnp.float32(alpha) * g), colnorm(g), atol=2e-4, rtol=2e-4
    )


def test_colnorm_zero_column_is_zero():
    g = jnp.zeros((8, 5), jnp.float32).at[:, 2].set(1.0)
    out = np.asarray(colnorm(g))
    assert np.all(out[:, 0] == 0.0) and np.all(out[:, 1] == 0.0)
    np.testing.assert_allclose(np.linalg.norm(out[:, 2]), 1.0, atol=1e-6)


def test_colnorm_idempotent():
    g = _mat(3, 16, 24)
    once = colnorm(g)
    np.testing.assert_allclose(colnorm(once), once, atol=1e-5)


@pytest.mark.parametrize("dim,tile", [(1, 128), (97, 128), (128, 128), (130, 64)])
def test_pick_tile_divides(dim, tile):
    t = _pick_tile(dim, tile)
    assert 1 <= t <= min(tile, dim) and dim % t == 0


# --------------------------------------------------------------------------
# Fused update kernels
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(DIMS, DIMS, SEEDS,
       st.floats(min_value=1e-5, max_value=1.0),
       st.floats(min_value=0.0, max_value=0.999))
def test_scale_update_momentum_matches_ref(d_in, d_out, seed, lr, beta):
    p, m, g = _mat(seed, d_in, d_out), _mat(seed + 1, d_in, d_out), _mat(seed + 2, d_in, d_out)
    pn, mn = scale_update_momentum(p, m, g, jnp.float32(lr), jnp.float32(beta))
    pr, mr = ref.scale_update_ref(p, m, g, lr, beta, True)
    np.testing.assert_allclose(mn, mr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(pn, pr, atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(DIMS, DIMS, SEEDS, st.floats(min_value=1e-5, max_value=1.0))
def test_scale_update_plain_matches_ref(d_in, d_out, seed, lr):
    p, g = _mat(seed, d_in, d_out), _mat(seed + 1, d_in, d_out)
    pn = scale_update_plain(p, g, jnp.float32(lr))
    pr, _ = ref.scale_update_ref(p, jnp.zeros_like(p), g, lr, 0.0, False)
    np.testing.assert_allclose(pn, pr, atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(DIMS, DIMS, SEEDS, st.integers(min_value=1, max_value=1000))
def test_adam_update_matches_ref(d_in, d_out, seed, step):
    p, g = _mat(seed, d_in, d_out), _mat(seed + 1, d_in, d_out)
    m, v = 0.1 * _mat(seed + 2, d_in, d_out), jnp.abs(0.1 * _mat(seed + 3, d_in, d_out))
    pn, mn, vn = adam_update(p, m, v, g, 1e-3, 0.9, 0.999, 1e-8, float(step))
    pr, mr, vr = ref.adam_update_ref(p, m, v, g, 1e-3, 0.9, 0.999, 1e-8, float(step))
    np.testing.assert_allclose(mn, mr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(vn, vr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(pn, pr, atol=1e-5, rtol=1e-4)


def test_adam_update_vector_param():
    """Vectors route through the same kernel via (1, n) reshape."""
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(size=(33,)).astype(np.float32))
    m = v = jnp.zeros_like(p)
    g = jnp.asarray(rng.normal(size=(33,)).astype(np.float32))
    pn, mn, vn = adam_update(p, m, v, g, 1e-3, 0.9, 0.999, 1e-8, 1.0)
    pr, mr, vr = ref.adam_update_ref(p, m, v, g, 1e-3, 0.9, 0.999, 1e-8, 1.0)
    assert pn.shape == (33,)
    np.testing.assert_allclose(pn, pr, atol=1e-6)


def test_scale_momentum_huge_gradients_stable():
    """Column normalization bounds the update regardless of gradient scale
    (the Fig. 3 stability argument)."""
    p = jnp.zeros((16, 8), jnp.float32)
    m = jnp.zeros_like(p)
    g = jnp.full((16, 8), 1e20, jnp.float32)
    pn, _ = scale_update_momentum(p, m, g, jnp.float32(0.1), jnp.float32(0.9))
    assert np.all(np.isfinite(np.asarray(pn)))
    assert np.abs(np.asarray(pn)).max() <= 0.1 + 1e-6
