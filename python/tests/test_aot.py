"""AOT pipeline tests: manifest consistency and HLO-text artifact hygiene.

Runs the quick builder into a temp dir (fast) and, when the full
artifact tree exists at ../artifacts, validates it too.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, configs, model, optimizers

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def quick_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("art")
    aot.build(str(out), ["s60m"], quick=True)
    return str(out)


def _load_manifest(d):
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)


def test_manifest_written(quick_dir):
    m = _load_manifest(quick_dir)
    assert m["version"] == 1
    assert "s60m" in m["sizes"]
    assert "update_scale_s60m" in m["artifacts"]


def test_every_artifact_file_exists(quick_dir):
    m = _load_manifest(quick_dir)
    for name, entry in m["artifacts"].items():
        path = os.path.join(quick_dir, entry["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, name


def test_update_io_layout(quick_dir):
    """update artifact I/O = params + state (+grads, lr, step) -> params + state."""
    m = _load_manifest(quick_dir)
    cfg = configs.SIZES["s60m"]
    n_params = len(model.param_specs(cfg))
    for oname in ("scale", "adam"):
        entry = m["artifacts"][f"update_{oname}_s60m"]
        n_state = len(m["state_specs"][f"{oname}_s60m"])
        assert len(entry["inputs"]) == 2 * n_params + n_state + 2
        assert len(entry["outputs"]) == n_params + n_state
        # outputs mirror param shapes then state shapes
        for spec, out in zip(model.param_specs(cfg), entry["outputs"]):
            assert list(spec[2]) == out["shape"]


def test_fwd_bwd_io_layout(quick_dir):
    m = _load_manifest(quick_dir)
    cfg = configs.SIZES["s60m"]
    entry = m["artifacts"]["fwd_bwd_s60m"]
    n = len(model.param_specs(cfg))
    assert len(entry["inputs"]) == n + 1
    assert entry["inputs"][-1]["dtype"] == "int32"
    assert entry["inputs"][-1]["shape"] == [m["microbatch"], cfg.seq_len + 1]
    assert len(entry["outputs"]) == n + 1  # loss + grads
    assert entry["outputs"][0]["shape"] == []


def test_state_specs_match_registry(quick_dir):
    m = _load_manifest(quick_dir)
    cfg = configs.SIZES["s60m"]
    for oname in ("scale", "adam"):
        want = optimizers.REGISTRY[oname].state_specs(cfg)
        got = m["state_specs"][f"{oname}_s60m"]
        assert [(e["name"], tuple(e["shape"])) for e in got] == [
            (n, tuple(s)) for n, s in want
        ]


def test_param_layers_labelled(quick_dir):
    m = _load_manifest(quick_dir)
    layers = {p["layer"] for p in m["sizes"]["s60m"]["params"]}
    assert {"embed", "lm_head", "block0", "block1"} <= layers


def test_paper_dims_embedded(quick_dir):
    m = _load_manifest(quick_dir)
    assert m["paper_dims"]["7B"]["d_model"] == 4096
    assert m["paper_dims"]["1B"]["vocab"] == 32000


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="full artifact tree not built")
def test_full_tree_consistent():
    m = _load_manifest(ART)
    # every referenced file exists; every size has model artifacts
    for name, entry in m["artifacts"].items():
        assert os.path.exists(os.path.join(ART, entry["file"])), name
    for sname in m["sizes"]:
        for kind in ("init", "fwd_bwd", "eval", "varprobe"):
            assert f"{kind}_{sname}" in m["artifacts"], (kind, sname)
    # the full zoo exists for the ablation size
    for oname in optimizers.CORE_SET + optimizers.NORM_SET + optimizers.ABLATION_SET:
        assert f"update_{oname}_s130m" in m["artifacts"], oname
    # norm micro-artifacts for every bench dim
    for d in m["norm_bench_dims"]:
        for op in ("col", "row", "sign", "ns"):
            assert f"norm_{op}_{d}" in m["artifacts"]
