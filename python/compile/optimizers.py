"""L2: the full optimizer zoo of the paper's evaluation section.

Every optimizer is expressed in one shared per-parameter framework:

  plan(cfg)   -> for each model parameter, a *strategy tag* plus the
                 auxiliary state slots (name, shape) that tag needs;
  update(...) -> walks parameters in canonical order, slices the flat
                 state list, applies the per-parameter rule, reassembles.

The flat, deterministic state layout is what aot.py serializes into
artifacts/manifest.json so the Rust coordinator can allocate and thread
optimizer state buffers without knowing any optimizer's internals.

Paper fidelity notes
--------------------
* Vector parameters (norm gains) always get Adam — Appendix C, "for all
  vector parameters we employ the Adam optimizer". Exceptions: the pure
  `sgd`/`sgd_momentum` baselines (they are the thing being shown to fail).
* GaLore / Fira / APOLLO(-Mini) / SWAN run full Adam on the first and
  last layers (Section 4, "worth noticing").
* SCALE  = column-wise normalization everywhere + first-order momentum
  *only on the LM head* (Algorithm 1). The matrix hot path calls the L1
  Pallas kernels (fused_update.py).
* Substitutions (documented in DESIGN.md §3): exact-SVD -> Newton-Schulz;
  GaLore's SVD projector -> NS randomized range finder refreshed every
  PROJ_REFRESH steps; Stable-SPAM -> Adam + spike-aware clipping +
  periodic momentum reset.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    adam_update,
    scale_update_momentum,
    scale_update_plain,
)
from .kernels.ref import colnorm_ref, rownorm_ref
from .model import param_specs
from .newton_schulz import ns_orth

# Shared hyperparameters (paper Appendix C and the methods' defaults).
BETA = 0.9            # first-order momentum (SCALE last layer, Muon, SGD-M)
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
PROJ_REFRESH = 50     # GaLore/Fira/APOLLO projector refresh cadence (steps)
SPAM_RESET = 500      # Stable-SPAM momentum reset cadence
SPAM_THETA = 2.0      # Stable-SPAM spike threshold multiplier
NS_STEPS = 5
_PROJ_KEY = 0xA90110  # seed root for random projections


def _rank_for(shape):
    """Low-rank r for GaLore/Fira/APOLLO on a (d_in, d_out) matrix."""
    return max(1, min(shape) // 16)


# --------------------------------------------------------------------------
# Per-parameter primitive rules
# --------------------------------------------------------------------------

def _adam(p, sts, g, lr, step):
    m, v = sts
    pn, mn, vn = adam_update(p, m, v, g, lr, ADAM_B1, ADAM_B2, ADAM_EPS, step)
    return pn, [mn, vn]


def _adam_jnp(p, sts, g, lr, step):
    """Plain-jnp Adam used inside lax.cond-free compositions (Stable-SPAM)."""
    m, v = sts
    mn = ADAM_B1 * m + (1 - ADAM_B1) * g
    vn = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mh = mn / (1 - ADAM_B1**step)
    vh = vn / (1 - ADAM_B2**step)
    return p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS), [mn, vn]


def _spam(p, sts, g, lr, step):
    """Stable-SPAM reconstruction: spike-aware clip + periodic mmt reset.

    AdaClip is modeled as a decaying per-element running max of |g|;
    entries jumping past SPAM_THETA x that history are clipped (at step 1
    the history is |g| itself, so nothing clips). Momentum resets every
    SPAM_RESET steps with bias correction restarted from the reset.
    """
    m, v, gmax = sts
    gmax_n = jnp.maximum(0.999 * gmax, jnp.abs(g))
    thresh = SPAM_THETA * gmax_n + 1e-12
    g_c = jnp.clip(g, -thresh, thresh)
    reset = jnp.asarray(step % SPAM_RESET == 0, g.dtype)
    m = m * (1 - reset)
    v = v * (1 - reset)
    # steps since the last reset, counting this one (1-based):
    #   step < R: step;  step = kR: 1;  else: step mod R + 1
    r = jnp.mod(step, float(SPAM_RESET))
    eff = jnp.where(step < SPAM_RESET, step, jnp.where(r == 0.0, 1.0, r + 1.0))
    mn = ADAM_B1 * m + (1 - ADAM_B1) * g_c
    vn = ADAM_B2 * v + (1 - ADAM_B2) * g_c * g_c
    mh = mn / (1 - ADAM_B1**eff)
    vh = vn / (1 - ADAM_B2**eff)
    return p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS), [mn, vn, gmax_n]


def _sgd(p, sts, g, lr, step):
    return p - lr * g, []


def _sgd_m(p, sts, g, lr, step):
    (m,) = sts
    mn = BETA * m + (1 - BETA) * g
    return p - lr * mn, [mn]


def _norm_plain(norm):
    def rule(p, sts, g, lr, step):
        return p - lr * norm(g), []

    return rule


def _scale_head(p, sts, g, lr, step):
    """SCALE last-layer rule — the fused L1 Pallas kernel (momentum path)."""
    (m,) = sts
    pn, mn = scale_update_momentum(p, m, g, lr, jnp.float32(BETA))
    return pn, [mn]


def _scale_plain(p, sts, g, lr, step):
    """SCALE stateless rule — the fused L1 Pallas kernel (plain path)."""
    return scale_update_plain(p, g, lr), []


def _mmt_norm(norm):
    """Momentum + arbitrary normalization (Table 13 variants, Muon core)."""

    def rule(p, sts, g, lr, step):
        (m,) = sts
        mn = BETA * m + (1 - BETA) * g
        return p - lr * norm(mn), [mn]

    return rule


def _muon_matrix(p, sts, g, lr, step):
    (m,) = sts
    mn = BETA * m + (1 - BETA) * g
    d = ns_orth(mn, NS_STEPS)
    # Moonlight-style RMS matching so one global LR serves all shapes.
    scale = 0.2 * jnp.sqrt(jnp.float32(max(p.shape)))
    return p - lr * scale * d, [mn]


def _swan_matrix(p, sts, g, lr, step):
    """SWAN hidden-matrix rule: row-norm then NS whitening (polar factor)."""
    gw = ns_orth(rownorm_ref(g), NS_STEPS)
    scale = 0.2 * jnp.sqrt(jnp.float32(max(p.shape)))
    return p - lr * scale * gw, []


def _proj_omega(shape, r, step, idx):
    """Deterministic pseudo-random sketch matrix, refreshed with the epoch.

    `step` is a traced f32 (1-based); the epoch counter folds into a fixed
    root key so projections are reproducible across runs and processes.
    """
    epoch = jnp.asarray((step - 1.0) // PROJ_REFRESH, jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(_PROJ_KEY), epoch * 4096 + idx)
    return jax.random.normal(key, (shape[1], r), jnp.float32) / jnp.sqrt(r)


def _galore_rule(idx, with_residual):
    """GaLore (and Fira when with_residual): low-rank Adam on Pᵀg."""

    def rule(p, sts, g, lr, step):
        P, m, v = sts
        r = P.shape[1]
        # Refresh at steps 1, 1+T, 1+2T, ... (step is 1-based). lax.cond
        # skips the NS work on the other PROJ_REFRESH-1 steps.
        P = jax.lax.cond(
            jnp.mod(step - 1.0, float(PROJ_REFRESH)) == 0.0,
            lambda: ns_orth(g @ _proj_omega(g.shape, r, step, idx), NS_STEPS),
            lambda: P,
        )
        g_lo = P.T @ g                                  # (r, d_out)
        mn = ADAM_B1 * m + (1 - ADAM_B1) * g_lo
        vn = ADAM_B2 * v + (1 - ADAM_B2) * g_lo * g_lo
        mh = mn / (1 - ADAM_B1**step)
        vh = vn / (1 - ADAM_B2**step)
        d_lo = mh / (jnp.sqrt(vh) + ADAM_EPS)
        d = P @ d_lo
        if with_residual:
            # Fira: re-introduce the full-rank residual, scaled by the
            # low-rank adaptivity ratio phi = ||d_lo|| / ||g_lo||.
            resid = g - P @ g_lo
            phi = jnp.sqrt(jnp.sum(d_lo * d_lo)) / (
                jnp.sqrt(jnp.sum(g_lo * g_lo)) + 1e-12
            )
            d = d + phi * resid
        return p - lr * d, [P, mn, vn]

    return rule


def _apollo_rule(idx, rank1):
    """APOLLO: channel-wise gradient scaling estimated in a random
    low-dimensional space; APOLLO-Mini (rank1) uses tensor-wise scaling."""

    def rule(p, sts, g, lr, step):
        m, v = sts
        r = m.shape[0]
        omega = _proj_omega((g.shape[1], g.shape[0]), r, step, idx)  # (d_in, r)
        g_lo = omega.T @ g                                # (r, d_out)
        mn = ADAM_B1 * m + (1 - ADAM_B1) * g_lo
        vn = ADAM_B2 * v + (1 - ADAM_B2) * g_lo * g_lo
        mh = mn / (1 - ADAM_B1**step)
        vh = vn / (1 - ADAM_B2**step)
        d_lo = mh / (jnp.sqrt(vh) + ADAM_EPS)
        if rank1:
            s = jnp.sqrt(jnp.sum(d_lo * d_lo)) / (
                jnp.sqrt(jnp.sum(g_lo * g_lo)) + 1e-12
            )
            d = s * g
        else:
            num = jnp.sqrt(jnp.sum(d_lo * d_lo, axis=0))  # per column
            den = jnp.sqrt(jnp.sum(g_lo * g_lo, axis=0)) + 1e-12
            d = g * (num / den)[None, :]
        return p - lr * d, [mn, vn]

    return rule


def _norm_larger_dim(g):
    """Table 13 row 4: normalize along whichever dimension is larger."""
    return colnorm_ref(g) if g.shape[0] >= g.shape[1] else rownorm_ref(g)


# --------------------------------------------------------------------------
# Optimizer definitions
# --------------------------------------------------------------------------

class Optimizer:
    """A named plan: param spec -> (rule, [(state suffix, shape)])."""

    def __init__(self, name, plan_fn):
        self.name = name
        self._plan_fn = plan_fn

    def plan(self, cfg):
        """[(rule, [(state_name, shape)])] aligned with param_specs(cfg)."""
        out = []
        for idx, (name, kind, shape) in enumerate(param_specs(cfg)):
            rule, slots = self._plan_fn(idx, name, kind, shape)
            out.append((rule, [(f"{name}.{suf}", shp) for suf, shp in slots]))
        return out

    def state_specs(self, cfg):
        return [slot for _, slots in self.plan(cfg) for slot in slots]

    def init_state(self, cfg):
        """Zeros for every slot except GaLore projectors (identity-ish init
        is irrelevant: they are refreshed at step 1 since 1 % T != 0 -> we
        force refresh at step 1 via zero-P detection being unnecessary —
        projectors refresh when step % PROJ_REFRESH == 0 and step counting
        starts at 0 for the first update's refresh)."""
        return [jnp.zeros(shp, jnp.float32) for _, shp in self.state_specs(cfg)]

    def update(self, cfg, params, state, grads, lr, step):
        """Apply one optimizer step. `lr` f32 scalar, `step` f32 scalar
        (1-based). Returns (new_params, new_state) as flat lists."""
        plan = self.plan(cfg)
        new_params, new_state, cursor = [], [], 0
        for (rule, slots), p, g in zip(plan, params, grads):
            sts = state[cursor : cursor + len(slots)]
            cursor += len(slots)
            pn, stn = rule(p, sts, g, lr, step)
            new_params.append(pn)
            new_state.extend(stn)
        assert cursor == len(state)
        return new_params, new_state


def _mk(name, matrix_rule_fn, head_rule_fn=None, embed_rule_fn=None,
        vector_adam=True, matrix_slots=None, head_slots=None,
        embed_slots=None):
    """Build an Optimizer from per-kind rules.

    *_rule_fn: (idx, shape) -> rule; *_slots: shape -> [(suffix, shp)].
    head/embed default to the matrix treatment.
    """
    matrix_slots = matrix_slots or (lambda shape: [])
    head_rule_fn = head_rule_fn or matrix_rule_fn
    embed_rule_fn = embed_rule_fn or matrix_rule_fn
    head_slots = head_slots if head_slots is not None else matrix_slots
    embed_slots = embed_slots if embed_slots is not None else matrix_slots

    def plan_fn(idx, pname, kind, shape):
        if kind == "vector":
            if vector_adam:
                return _adam, [("m", shape), ("v", shape)]
            return _sgd, []
        if kind == "head":
            return head_rule_fn(idx, shape), head_slots(shape)
        if kind == "embed":
            return embed_rule_fn(idx, shape), embed_slots(shape)
        return matrix_rule_fn(idx, shape), matrix_slots(shape)

    return Optimizer(name, plan_fn)


_adam_slots = lambda shape: [("m", shape), ("v", shape)]
_mmt_slots = lambda shape: [("m", shape)]
_spam_slots = lambda shape: [("m", shape), ("v", shape), ("gmax", shape)]
_galore_slots = lambda shape: [
    ("P", (shape[0], _rank_for(shape))),
    ("m", (_rank_for(shape), shape[1])),
    ("v", (_rank_for(shape), shape[1])),
]
_apollo_slots = lambda shape: [
    ("m", (_rank_for(shape), shape[1])),
    ("v", (_rank_for(shape), shape[1])),
]
_apollo1_slots = lambda shape: [("m", (1, shape[1])), ("v", (1, shape[1]))]


def _registry():
    const = lambda rule: (lambda idx, shape: rule)
    opts = [
        # --- plain baselines -------------------------------------------------
        _mk("sgd", const(_sgd), vector_adam=False),
        _mk("sgd_momentum", const(_sgd_m), vector_adam=False,
            matrix_slots=_mmt_slots),
        _mk("adam", const(_adam), matrix_slots=_adam_slots),
        _mk("stable_spam", const(_spam), matrix_slots=_spam_slots),
        # --- pure normalization ablations (Table 2) --------------------------
        _mk("sign_sgd", const(_norm_plain(jnp.sign))),
        _mk("sgd_colnorm", const(_scale_plain)),
        _mk("sgd_rownorm", const(_norm_plain(rownorm_ref))),
        _mk("sgd_ns", const(_norm_plain(lambda g: ns_orth(g, NS_STEPS)))),
        # --- SCALE (ours) and ablations (Alg. 1, Tables 3/8) -----------------
        _mk("scale", const(_scale_plain),
            head_rule_fn=const(_scale_head), head_slots=_mmt_slots),
        _mk("scale_first_last", const(_scale_plain),
            head_rule_fn=const(_scale_head), head_slots=_mmt_slots,
            embed_rule_fn=const(_scale_head), embed_slots=_mmt_slots),
        _mk("ns_mmt_last", const(_norm_plain(lambda g: ns_orth(g, NS_STEPS))),
            head_rule_fn=const(_mmt_norm(lambda g: ns_orth(g, NS_STEPS))),
            head_slots=_mmt_slots),
        # --- SOTA memory-efficient baselines ---------------------------------
        _mk("muon", const(_muon_matrix), matrix_slots=_mmt_slots,
            head_rule_fn=const(_adam), head_slots=_adam_slots,
            embed_rule_fn=const(_adam), embed_slots=_adam_slots),
        _mk("galore", lambda idx, shape: _galore_rule(idx, False),
            matrix_slots=_galore_slots,
            head_rule_fn=const(_adam), head_slots=_adam_slots,
            embed_rule_fn=const(_adam), embed_slots=_adam_slots),
        _mk("fira", lambda idx, shape: _galore_rule(idx, True),
            matrix_slots=_galore_slots,
            head_rule_fn=const(_adam), head_slots=_adam_slots,
            embed_rule_fn=const(_adam), embed_slots=_adam_slots),
        _mk("apollo", lambda idx, shape: _apollo_rule(idx, False),
            matrix_slots=_apollo_slots,
            head_rule_fn=const(_adam), head_slots=_adam_slots,
            embed_rule_fn=const(_adam), embed_slots=_adam_slots),
        _mk("apollo_mini", lambda idx, shape: _apollo_rule(idx, True),
            matrix_slots=_apollo1_slots,
            head_rule_fn=const(_adam), head_slots=_adam_slots,
            embed_rule_fn=const(_adam), embed_slots=_adam_slots),
        _mk("swan", const(_swan_matrix),
            head_rule_fn=const(_adam), head_slots=_adam_slots,
            embed_rule_fn=const(_adam), embed_slots=_adam_slots),
        # --- Table 13 mixed-normalization ablations (all mmt-last) -----------
        _mk("mix_col_last_row_rest", const(_norm_plain(rownorm_ref)),
            head_rule_fn=const(_mmt_norm(colnorm_ref)), head_slots=_mmt_slots),
        _mk("mix_row_first_col_rest", const(_scale_plain),
            head_rule_fn=const(_scale_head), head_slots=_mmt_slots,
            embed_rule_fn=const(_norm_plain(rownorm_ref))),
        _mk("mix_larger_dim", const(_norm_plain(_norm_larger_dim)),
            head_rule_fn=const(_mmt_norm(_norm_larger_dim)),
            head_slots=_mmt_slots),
        _mk("mix_row_last_col_rest", const(_scale_plain),
            head_rule_fn=const(_mmt_norm(rownorm_ref)), head_slots=_mmt_slots),
    ]
    return {o.name: o for o in opts}


REGISTRY = _registry()

# Subsets used by aot.py to bound artifact count (DESIGN.md §5).
CORE_SET = ["sgd", "sgd_momentum", "adam", "stable_spam", "muon", "galore",
            "fira", "apollo", "apollo_mini", "swan", "scale"]
NORM_SET = ["sign_sgd", "sgd_colnorm", "sgd_rownorm", "sgd_ns",
            "ns_mmt_last"]
ABLATION_SET = ["scale_first_last", "mix_col_last_row_rest",
                "mix_row_first_col_rest", "mix_larger_dim",
                "mix_row_last_col_rest"]
