"""AOT lowering: every L2 computation -> artifacts/*.hlo.txt + manifest.json.

Run once by `make artifacts`; Python never appears on the training path.

Interchange format is **HLO text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifact inventory (DESIGN.md §2/§5):
  init_<size>        (seed:i32)                        -> (params...)
  fwd_bwd_<size>     (params..., batch[MB,S+1]:i32)    -> (loss, grads...)
  eval_<size>        (params..., batch[MB,S+1]:i32)    -> (loss,)
  update_<opt>_<size>(params..., state..., grads..., lr:f32, step:f32)
                                                       -> (params..., state...)
  varprobe_<size>    (params..., small[MB], big[4*MB]) -> (per-param var...)
  norm_<op>_<d>      (x[d,d]:f32)                      -> (y[d,d],)
All outputs are lowered with return_tuple=True; the Rust runtime unwraps
the tuple generically.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, optimizers
from .kernels import colnorm, rownorm, sign
from .newton_schulz import ns_orth

MICROBATCH = 4           # sequences per fwd_bwd execution (DDP shard size)
VARPROBE_BIG_FACTOR = 4  # big batch = 4x microbatch (paper footnote 3)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.artifacts = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, in_specs, meta):
        """Lower fn at in_specs, write <name>.hlo.txt, record manifest entry.

        keep_unused=True: the executable's input signature must match the
        manifest exactly even when an optimizer ignores an input (e.g.
        SGD ignores `step`) — jit would otherwise prune it.
        """
        lowered = jax.jit(fn, keep_unused=True).lower(
            *[_spec(s, d) for _, s, d in in_specs]
        )
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = lowered.out_info
        out_meta = [
            _io(f"out{i}", o.shape, str(o.dtype)) for i, o in enumerate(outs)
        ]
        entry = {
            "file": fname,
            "inputs": [_io(n, s, "int32" if d == I32 else "float32")
                       for n, s, d in in_specs],
            "outputs": out_meta,
        }
        entry.update(meta)
        self.artifacts[name] = entry
        print(f"  {name}: {len(text)/1024:.0f} KiB, "
              f"{len(in_specs)} in / {len(out_meta)} out", flush=True)


def _layer_of(pname):
    """Variance-analysis grouping label (Fig. 4): embed / blockN / lm_head."""
    head = pname.split(".")[0]
    return head if head.startswith("block") or head in ("embed", "lm_head", "pos_embed") else head


def build(out_dir, sizes, quick=False):
    b = Builder(out_dir)
    manifest = {
        "version": 1,
        "microbatch": MICROBATCH,
        "varprobe_big_factor": VARPROBE_BIG_FACTOR,
        "sizes": {},
        "state_specs": {},
        "optim_hparams": {
            "beta": optimizers.BETA,
            "adam_b1": optimizers.ADAM_B1,
            "adam_b2": optimizers.ADAM_B2,
            "adam_eps": optimizers.ADAM_EPS,
            "proj_refresh": optimizers.PROJ_REFRESH,
            "spam_reset": optimizers.SPAM_RESET,
        },
        "paper_dims": configs.PAPER_DIMS,
        "norm_bench_dims": list(configs.NORM_BENCH_DIMS),
    }

    # ---- per-size model artifacts ---------------------------------------
    for sname in sizes:
        cfg = configs.SIZES[sname]
        specs = model.param_specs(cfg)
        pins = [(n, shp, F32) for n, _, shp in specs]
        batch = ("batch", (MICROBATCH, cfg.seq_len + 1), I32)
        big = ("big_batch", (MICROBATCH * VARPROBE_BIG_FACTOR, cfg.seq_len + 1), I32)

        manifest["sizes"][sname] = {
            **cfg.to_dict(),
            "params": [
                {"name": n, "kind": k, "shape": list(shp), "layer": _layer_of(n)}
                for n, k, shp in specs
            ],
        }

        print(f"[size {sname}] ({cfg.param_count()/1e6:.2f}M params)", flush=True)
        b.emit(f"init_{sname}",
               lambda seed, cfg=cfg: tuple(model.init_params(cfg, seed)),
               [("seed", (), I32)], {"kind": "init", "size": sname})
        b.emit(f"fwd_bwd_{sname}",
               lambda *a, cfg=cfg, n=len(specs): model.fwd_bwd(cfg, a[:n], a[n]),
               pins + [batch], {"kind": "fwd_bwd", "size": sname})
        b.emit(f"eval_{sname}",
               lambda *a, cfg=cfg, n=len(specs): (model.eval_step(cfg, a[:n], a[n]),),
               pins + [batch], {"kind": "eval", "size": sname})
        b.emit(f"varprobe_{sname}",
               lambda *a, cfg=cfg, n=len(specs): model.grad_variance_probe(
                   cfg, a[:n], a[n], a[n + 1]),
               pins + [batch, big], {"kind": "varprobe", "size": sname})

        # ---- optimizer update artifacts ----------------------------------
        if quick:
            opt_names = ["scale", "adam"]
        elif sname == "s130m":
            opt_names = (optimizers.CORE_SET + optimizers.NORM_SET
                         + optimizers.ABLATION_SET)
        elif sname in ("e2e", "gpt2s"):
            opt_names = optimizers.CORE_SET
        else:
            opt_names = optimizers.CORE_SET + optimizers.NORM_SET
        for oname in opt_names:
            opt = optimizers.REGISTRY[oname]
            st_specs = opt.state_specs(cfg)
            key = f"{oname}_{sname}"
            manifest["state_specs"][key] = [
                {"name": n, "shape": list(shp)} for n, shp in st_specs
            ]
            np_, ns_ = len(specs), len(st_specs)
            sins = [(n, shp, F32) for n, shp in st_specs]
            gins = [(f"grad.{n}", shp, F32) for n, _, shp in specs]

            def upd(*a, opt=opt, cfg=cfg, np_=np_, ns_=ns_):
                params = list(a[:np_])
                state = list(a[np_: np_ + ns_])
                grads = list(a[np_ + ns_: np_ + ns_ + np_])
                lr, step = a[-2], a[-1]
                pn, sn = opt.update(cfg, params, state, grads, lr, step)
                return tuple(pn) + tuple(sn)

            b.emit(f"update_{key}", upd,
                   pins + sins + gins + [("lr", (), F32), ("step", (), F32)],
                   {"kind": "update", "size": sname, "optimizer": oname})

    # ---- normalization micro-artifacts (Table 1 / parity tests) ----------
    # tile=whole-matrix: under interpret=True a multi-step grid lowers to
    # an HLO while-loop whose per-step dispatch dominates the elementwise
    # work (§Perf L1-1: sign d=512 was 24ms with 128-wide stripes, the
    # grid loop, not the arithmetic). On real TPU the stripe width would
    # instead be set by VMEM (DESIGN.md §7).
    norm_ops = {
        "col": lambda x: (colnorm(x, tile=x.shape[1]),),
        "row": lambda x: (rownorm(x, tile=x.shape[0]),),
        "sign": lambda x: (sign(x, tile=x.shape[1]),),
        "ns": lambda x: (ns_orth(x, optimizers.NS_STEPS),),
    }
    dims = configs.NORM_BENCH_DIMS if not quick else (128,)
    print("[norm micro-artifacts]", flush=True)
    for d in dims:
        for op, fn in norm_ops.items():
            b.emit(f"norm_{op}_{d}", fn, [("x", (d, d), F32)],
                   {"kind": "norm", "op": op, "dim": d})

    manifest["artifacts"] = b.artifacts
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(b.artifacts)} artifacts + manifest.json to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--sizes", default="s60m,s130m,s350m,gpt2s,e2e",
                    help="comma-separated size tags (see configs.SIZES)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny artifact set for CI smoke")
    args = ap.parse_args()
    sizes = [s for s in args.sizes.split(",") if s]
    for s in sizes:
        if s not in configs.SIZES:
            sys.exit(f"unknown size {s!r}; have {sorted(configs.SIZES)}")
    build(args.out, sizes, quick=args.quick)


if __name__ == "__main__":
    main()
