"""L1 Pallas kernels: fused optimizer updates (the training hot path).

``scale_update`` fuses Algorithm 1's inner body for one weight matrix —
EMA (last layer only), column-wise normalization, and the parameter
apply — into a single kernel: one HBM read of (p, m, g) and one write of
(p', m') per column stripe, instead of three separate elementwise passes
(3x the arithmetic intensity; see DESIGN.md §7 and EXPERIMENTS.md §Perf).

``adam_update`` is the fused Adam baseline (eq. 3) used for vector
parameters in every optimizer and for the Adam/Stable-SPAM baselines.

Both run under ``interpret=True`` (CPU PJRT cannot run Mosaic); they are
called from L2 (optimizers.py) so they lower into the same AOT HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .colnorm import EPS, _pick_tile, DEFAULT_TILE


def _scale_mmt_kernel(p_ref, m_ref, g_ref, lr_ref, beta_ref, po_ref, mo_ref):
    """Momentum path (last layer): m' = beta*m + (1-beta)*g; p -= lr*C(m')."""
    g = g_ref[...]
    beta = beta_ref[0]
    m_new = beta * m_ref[...] + (1.0 - beta) * g
    norms = jnp.sqrt(jnp.sum(m_new * m_new, axis=0, keepdims=True))
    po_ref[...] = p_ref[...] - lr_ref[0] * (m_new / jnp.maximum(norms, EPS))
    mo_ref[...] = m_new


def _scale_plain_kernel(p_ref, g_ref, lr_ref, po_ref):
    """Stateless path (all other layers): p -= lr*C(g)."""
    g = g_ref[...]
    norms = jnp.sqrt(jnp.sum(g * g, axis=0, keepdims=True))
    po_ref[...] = p_ref[...] - lr_ref[0] * (g / jnp.maximum(norms, EPS))


@functools.partial(jax.jit, static_argnames=("tile",))
def scale_update_momentum(p, m, g, lr, beta, tile=DEFAULT_TILE):
    """Fused SCALE step with momentum (LM head). Returns (p', m').

    ``lr`` and ``beta`` are traced scalars, passed as (1,)-shaped
    operands so a single compiled artifact serves the whole LR schedule.
    """
    d_in, d_out = p.shape
    t = _pick_tile(d_out, tile)
    stripe = pl.BlockSpec((d_in, t), lambda j: (0, j))
    scalar = pl.BlockSpec((1,), lambda j: (0,))
    return pl.pallas_call(
        _scale_mmt_kernel,
        grid=(d_out // t,),
        in_specs=[stripe, stripe, stripe, scalar, scalar],
        out_specs=[stripe, stripe],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, m.dtype),
        ],
        interpret=True,
    )(p, m, g, jnp.reshape(lr, (1,)), jnp.reshape(beta, (1,)))


@functools.partial(jax.jit, static_argnames=("tile",))
def scale_update_plain(p, g, lr, tile=DEFAULT_TILE):
    """Fused stateless SCALE step (column-normalized SGD). Returns p'."""
    d_in, d_out = p.shape
    t = _pick_tile(d_out, tile)
    stripe = pl.BlockSpec((d_in, t), lambda j: (0, j))
    scalar = pl.BlockSpec((1,), lambda j: (0,))
    return pl.pallas_call(
        _scale_plain_kernel,
        grid=(d_out // t,),
        in_specs=[stripe, stripe, scalar],
        out_specs=stripe,
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        interpret=True,
    )(p, g, jnp.reshape(lr, (1,)))


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, s_ref, po_ref, mo_ref, vo_ref):
    g = g_ref[...]
    lr, beta1, beta2, eps, step = (s_ref[0], s_ref[1], s_ref[2], s_ref[3], s_ref[4])
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1**step)
    v_hat = v_new / (1.0 - beta2**step)
    po_ref[...] = p_ref[...] - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


@functools.partial(jax.jit, static_argnames=("tile",))
def adam_update(p, m, v, g, lr, beta1, beta2, eps, step, tile=DEFAULT_TILE):
    """Fused bias-corrected Adam step (eq. 3). Returns (p', m', v').

    Scalars travel as one packed (5,) vector: [lr, b1, b2, eps, step].
    Works on matrices and (reshaped) vectors alike.
    """
    p2 = p if p.ndim == 2 else p.reshape(1, -1)
    m2, v2, g2 = (x if x.ndim == 2 else x.reshape(1, -1) for x in (m, v, g))
    d_in, d_out = p2.shape
    t = _pick_tile(d_out, tile)
    stripe = pl.BlockSpec((d_in, t), lambda j: (0, j))
    scal = pl.BlockSpec((5,), lambda j: (0,))
    packed = jnp.stack(
        [
            jnp.asarray(lr, p2.dtype),
            jnp.asarray(beta1, p2.dtype),
            jnp.asarray(beta2, p2.dtype),
            jnp.asarray(eps, p2.dtype),
            jnp.asarray(step, p2.dtype),
        ]
    )
    po, mo, vo = pl.pallas_call(
        _adam_kernel,
        grid=(d_out // t,),
        in_specs=[stripe, stripe, stripe, stripe, scal],
        out_specs=[stripe, stripe, stripe],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, p2.dtype),
            jax.ShapeDtypeStruct(m2.shape, m2.dtype),
            jax.ShapeDtypeStruct(v2.shape, v2.dtype),
        ],
        interpret=True,
    )(p2, m2, v2, g2, packed)
    return po.reshape(p.shape), mo.reshape(m.shape), vo.reshape(v.shape)
