"""L1 Pallas kernels: the gradient-normalization family of eq. (6).

TPU-shaped schedule (see DESIGN.md §7): column-wise normalization reduces
along ``d_in`` (axis 0), so the BlockSpec tiles the *output* dimension —
every grid step sees a full ``(d_in, TILE)`` column stripe resident in
VMEM, computes the per-column L2 norms with a single sublane reduction,
and rescales in place. No cross-block accumulation, no second pass over
HBM. Row-wise normalization is the transpose schedule.

All kernels are launched with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain
HLO that AOT-exports cleanly (aot_recipe). Correctness against
``ref.py`` is enforced by ``python/tests/test_kernels.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-30

# Default column-stripe width. For the tiny models in this repo whole
# matrices fit in one block; the tile path is exercised whenever
# d_out > TILE (e.g. LM heads, vocab-sized axes) and by the unit tests.
DEFAULT_TILE = 128


def _pick_tile(dim, tile):
    """Largest divisor of ``dim`` that is <= tile (pallas needs an exact grid)."""
    t = min(tile, dim)
    while dim % t != 0:
        t -= 1
    return t


def _colnorm_kernel(g_ref, o_ref):
    g = g_ref[...]
    norms = jnp.sqrt(jnp.sum(g * g, axis=0, keepdims=True))
    o_ref[...] = g / jnp.maximum(norms, EPS)


@functools.partial(jax.jit, static_argnames=("tile",))
def colnorm(g, tile=DEFAULT_TILE):
    """Column-wise normalization C(G) as a Pallas kernel.

    Grid: one step per column stripe of width ``tile`` (full rows in
    VMEM so the axis-0 reduction stays on-chip).
    """
    d_in, d_out = g.shape
    t = _pick_tile(d_out, tile)
    return pl.pallas_call(
        _colnorm_kernel,
        grid=(d_out // t,),
        in_specs=[pl.BlockSpec((d_in, t), lambda j: (0, j))],
        out_specs=pl.BlockSpec((d_in, t), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(g)


def _rownorm_kernel(g_ref, o_ref):
    g = g_ref[...]
    norms = jnp.sqrt(jnp.sum(g * g, axis=1, keepdims=True))
    o_ref[...] = g / jnp.maximum(norms, EPS)


@functools.partial(jax.jit, static_argnames=("tile",))
def rownorm(g, tile=DEFAULT_TILE):
    """Row-wise normalization as a Pallas kernel (transpose schedule)."""
    d_in, d_out = g.shape
    t = _pick_tile(d_in, tile)
    return pl.pallas_call(
        _rownorm_kernel,
        grid=(d_in // t,),
        in_specs=[pl.BlockSpec((t, d_out), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((t, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(g)


def _sign_kernel(g_ref, o_ref):
    o_ref[...] = jnp.sign(g_ref[...])


@functools.partial(jax.jit, static_argnames=("tile",))
def sign(g, tile=DEFAULT_TILE):
    """Sign normalization (eq. 4) as a Pallas kernel; pure elementwise,
    tiled along columns only so arbitrarily wide matrices stream through
    VMEM."""
    d_in, d_out = g.shape
    t = _pick_tile(d_out, tile)
    return pl.pallas_call(
        _sign_kernel,
        grid=(d_out // t,),
        in_specs=[pl.BlockSpec((d_in, t), lambda j: (0, j))],
        out_specs=pl.BlockSpec((d_in, t), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(g)
