"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth that pytest/hypothesis checks the kernels
against, and they double as the *specification* of each operation as it
appears in the paper:

- ``colnorm_ref``  — eq. (6), "Column-wise normalization": each column of
  the ``d_in x d_out`` gradient is scaled to unit L2 norm (normalizing
  along the *output* dimension).
- ``rownorm_ref``  — eq. (6), "Row-wise normalization".
- ``sign_ref``     — eq. (6), "Sign normalization" (sign-SGD, eq. (4)).
- ``scale_update_ref`` — Algorithm 1 inner step for one weight matrix:
  optional EMA ``m = beta*m + (1-beta)*g`` followed by
  ``theta <- theta - lr * C(m)``.
- ``adam_update_ref``  — eq. (3) with bias correction, the Adam baseline.
"""

import jax.numpy as jnp

# Matches the paper's epsilon-free definition; we guard zero columns the
# same way every implementation here does: ||col|| -> max(||col||, EPS).
EPS = 1e-30


def colnorm_ref(g):
    """Column-wise normalization C(G): unit L2 norm along axis 0.

    G has shape (d_in, d_out); column j is G[:, j] (the weights feeding
    output unit j). Zero columns map to zero.
    """
    norms = jnp.sqrt(jnp.sum(g * g, axis=0, keepdims=True))
    return g / jnp.maximum(norms, EPS)


def rownorm_ref(g):
    """Row-wise normalization: unit L2 norm along axis 1."""
    norms = jnp.sqrt(jnp.sum(g * g, axis=1, keepdims=True))
    return g / jnp.maximum(norms, EPS)


def sign_ref(g):
    """Sign normalization sign(G) (eq. 4)."""
    return jnp.sign(g)


def scale_update_ref(p, m, g, lr, beta, use_momentum):
    """One SCALE step for a single weight matrix (Algorithm 1 body).

    If ``use_momentum`` (last layer): m' = beta*m + (1-beta)*g, direction
    C(m'). Otherwise m' = g (recorded directly) and direction C(g).
    Returns (p', m').
    """
    m_new = jnp.where(use_momentum, beta * m + (1.0 - beta) * g, g)
    p_new = p - lr * colnorm_ref(m_new)
    return p_new, m_new


def adam_update_ref(p, m, v, g, lr, beta1, beta2, eps, step):
    """Bias-corrected Adam (eq. 3). ``step`` is 1-based."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** step)
    v_hat = v_new / (1.0 - beta2 ** step)
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new
