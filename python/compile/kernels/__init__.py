"""L1: Pallas kernels for the SCALE optimizer hot path.

Public surface (all interpret=True; see module docstrings):
  colnorm, rownorm, sign          — normalization family (eq. 6)
  scale_update_momentum/plain     — fused Algorithm 1 inner step
  adam_update                     — fused Adam baseline (eq. 3)
"""

from .colnorm import colnorm, rownorm, sign  # noqa: F401
from .fused_update import (  # noqa: F401
    adam_update,
    scale_update_momentum,
    scale_update_plain,
)
