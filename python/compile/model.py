"""L2: the JAX model — a LLaMA-style decoder-only transformer.

Matches the paper's experimental substrate (Section 4 / Appendix C):
RMSNorm, rotary position embeddings, causal attention, SwiGLU MLP,
untied LM head, next-token cross-entropy. A GPT2-style variant (learned
positional embeddings + GELU MLP) backs the Appendix-F generality check.

Parameters are a *flat ordered list*; ``param_specs(cfg)`` is the single
source of truth for that order and for each parameter's role:

  kind = "embed"   — the first layer (paper: momentum ablation, App. E)
       | "matrix"  — hidden weight matrices, stored (d_in, d_out) so that
                     column j holds the weights feeding output unit j
                     (the orientation eq. (6) normalizes over)
       | "head"    — the LM head (d_model, |V|): the "last layer" whose
                     columns correspond to vocabulary tokens (App. M)
       | "vector"  — norm gains; every optimizer gives these Adam (App. C)

The same spec list is serialized into artifacts/manifest.json so the
Rust coordinator can allocate, checkpoint and route buffers generically.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    """[(name, kind, shape)] in canonical artifact order."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    specs = [("embed", "embed", (v, d))]
    if cfg.arch == "gpt2":
        specs.append(("pos_embed", "matrix", (cfg.seq_len, d)))
    for i in range(cfg.n_layers):
        p = f"block{i}."
        specs += [
            (p + "attn_norm", "vector", (d,)),
            (p + "wq", "matrix", (d, d)),
            (p + "wk", "matrix", (d, d)),
            (p + "wv", "matrix", (d, d)),
            (p + "wo", "matrix", (d, d)),
            (p + "mlp_norm", "vector", (d,)),
        ]
        if cfg.arch == "gpt2":
            specs += [(p + "w_up", "matrix", (d, f)), (p + "w_down", "matrix", (f, d))]
        else:
            specs += [
                (p + "w_gate", "matrix", (d, f)),
                (p + "w_up", "matrix", (d, f)),
                (p + "w_down", "matrix", (f, d)),
            ]
    specs += [("final_norm", "vector", (d,)), ("lm_head", "head", (d, v))]
    return specs


def init_params(cfg: ModelConfig, seed):
    """Deterministic init from an int32 seed (exported as init_<size>).

    Truncated-normal-free scheme: scaled normal, 1/sqrt(d_in) fan-in for
    matrices, N(0, 0.02) embeddings, ones for norm gains — the GPT/LLaMA
    convention.
    """
    key = jax.random.PRNGKey(seed)
    params = []
    for name, kind, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if kind == "vector":
            params.append(jnp.ones(shape, jnp.float32))
        elif kind == "embed" or name == "pos_embed":
            params.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:
            scale = 1.0 / jnp.sqrt(shape[0])
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def as_dict(cfg, params):
    return {name: p for (name, _, _), p in zip(param_specs(cfg), params)}


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _rmsnorm(x, gain, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def _rope(x, base=10000.0):
    """Rotary embedding over the last dim of x: (B, H, S, Dh)."""
    b, h, s, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(s, dtype=jnp.float32)
    ang = jnp.einsum("s,d->sd", t, freqs)  # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(cfg, h, wq, wk, wv, wo, use_rope=True):
    b, s, d = h.shape
    nh, dh = cfg.n_heads, cfg.head_dim

    def split(x):
        return x.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)

    q, k, v = split(h @ wq), split(h @ wk), split(h @ wv)
    if use_rope:
        q, k = _rope(q), _rope(k)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    att = jnp.where(mask, att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d) @ wo


def forward(cfg: ModelConfig, params, tokens):
    """tokens: (B, S) int32 -> logits (B, S, |V|)."""
    p = as_dict(cfg, params)
    h = p["embed"][tokens]
    if cfg.arch == "gpt2":
        h = h + p["pos_embed"][None, : tokens.shape[1], :]
    for i in range(cfg.n_layers):
        blk = f"block{i}."
        x = _rmsnorm(h, p[blk + "attn_norm"])
        h = h + _attention(
            cfg, x, p[blk + "wq"], p[blk + "wk"], p[blk + "wv"], p[blk + "wo"],
            use_rope=(cfg.arch != "gpt2"),
        )
        x = _rmsnorm(h, p[blk + "mlp_norm"])
        if cfg.arch == "gpt2":
            h = h + jax.nn.gelu(x @ p[blk + "w_up"]) @ p[blk + "w_down"]
        else:
            h = h + (jax.nn.silu(x @ p[blk + "w_gate"]) * (x @ p[blk + "w_up"])) @ p[blk + "w_down"]
    h = _rmsnorm(h, p["final_norm"])
    return h @ p["lm_head"]


def loss_fn(cfg: ModelConfig, params, batch):
    """batch: (B, S+1) int32. Mean next-token cross entropy (nats)."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# Exported computations (lowered by aot.py)
# --------------------------------------------------------------------------

def fwd_bwd(cfg: ModelConfig, params, batch):
    """(params..., batch) -> (loss, grads...). The per-step gradient
    computation the coordinator runs on every microbatch/shard."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, batch))(list(params))
    return (loss, *grads)


def eval_step(cfg: ModelConfig, params, batch):
    """(params..., batch) -> loss. Perplexity = exp(loss)."""
    return loss_fn(cfg, params, batch)


def grad_variance_probe(cfg: ModelConfig, params, small_batch, big_batch):
    """Per-layer variance estimator backing Fig. 4/6/7.

    Returns ||g_small_l - g_big_l||^2 / numel_l per parameter, where the
    big batch stands in for the true gradient (paper §2.2, footnote 3).
    """
    _, g_small = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, small_batch))(list(params))
    _, g_big = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, big_batch))(list(params))
    outs = [jnp.sum((a - b) ** 2) / a.size for a, b in zip(g_small, g_big)]
    return tuple(outs)


def make_jitted(cfg: ModelConfig):
    """Convenience jitted closures for the pytest suite."""
    n = len(param_specs(cfg))

    @jax.jit
    def _fwd_bwd(*args):
        return fwd_bwd(cfg, args[:n], args[n])

    @jax.jit
    def _eval(*args):
        return eval_step(cfg, args[:n], args[n])

    return _fwd_bwd, _eval


@functools.lru_cache(maxsize=None)
def _specs_cached(cfg):
    return param_specs(cfg)
