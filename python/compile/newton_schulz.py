"""Newton–Schulz orthogonalization — "singular-value normalization" (eq. 6).

The paper's Table 1/2 "singular-value (NS)" rows use the quintic
Newton–Schulz iteration popularized by Muon (Jordan et al., 2024): for
G = U Σ Vᵀ it converges to (approximately) U Vᵀ using only matmuls — no
SVD/LAPACK custom-calls, which the xla_extension 0.5.1 CPU runtime could
not execute anyway (DESIGN.md §3 substitution table).

Also the whitening step of our SWAN reconstruction: (GGᵀ)^{-1/2} G *is*
the orthogonal polar factor, i.e. exactly what NS computes.
"""

import jax.numpy as jnp

# Quintic iteration coefficients from Jordan et al. (2024).
_A, _B, _C = 3.4445, -4.7750, 2.0315


def ns_orth(g, steps: int = 5):
    """Approximate U Vᵀ of g via `steps` quintic NS iterations.

    Handles non-square matrices by operating on the short side (the
    iteration needs spectral norm <= 1, ensured by Frobenius prescale).
    """
    x = g.astype(jnp.float32)
    transpose = x.shape[0] > x.shape[1]
    if transpose:
        x = x.T
    x = x / (jnp.sqrt(jnp.sum(x * x)) + 1e-7)
    for _ in range(steps):
        a = x @ x.T
        b = _B * a + _C * (a @ a)
        x = _A * x + b @ x
    if transpose:
        x = x.T
    return x


def ns_range_finder(g, omega, steps: int = 5):
    """Randomized range finder with NS orthonormalization.

    Stand-in for GaLore's SVD projector (DESIGN.md §3): `g @ omega`
    sketches the dominant column space of g; NS orthonormalizes the
    (d_in, r) sketch so P has near-orthonormal columns. Matmuls only.
    """
    sketch = g @ omega  # (d_in, r)
    return ns_orth(sketch, steps=steps)
