"""Model-size table: the tiny-LLaMA simulation family and paper-scale dims.

The paper trains LLaMA 60M/130M/350M/1B/7B on C4 with 8xH200. This repo
runs on a single CPU core (repro band 0/5), so each paper size maps to a
scaled-down config with the *same layer inventory* — embedding, L
transformer blocks (RMSNorm + RoPE attention + SwiGLU), final norm,
untied LM head — and vocab >> d_model, preserving the LM-head column
structure the paper's analysis (Fig. 3/10, App. M) depends on.

``PAPER_DIMS`` carries the *real* LLaMA dims used by the memory
estimator (Appendix B) — those numbers reproduce exactly because memory
accounting is pure arithmetic.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str          # our tag, e.g. "s60m"
    paper_size: str    # the paper row this config simulates
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int          # SwiGLU hidden dim
    seq_len: int
    batch: int         # global batch (sequences) used by the trainer
    arch: str = "llama"  # "llama" | "gpt2" (App. F generality check)

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def param_count(self):
        """Total trainable parameters (matches model.init_params)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_block = 4 * d * d + 3 * d * f + 2 * d  # attn + swiglu + 2 norms
        if self.arch == "gpt2":
            # learned pos-emb, 2-matrix GELU MLP (d_ff used as hidden)
            per_block = 4 * d * d + 2 * d * f + 2 * d
            return v * d + self.seq_len * d + self.n_layers * per_block + d + d * v
        return v * d + self.n_layers * per_block + d + d * v

    def to_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["param_count"] = self.param_count()
        return d


# Tiny simulation family. vocab/d_model ratios kept LLaMA-like
# (vocab >> d) so last-layer dominance of small models carries over.
SIZES = {
    "s60m": ModelConfig("s60m", "60M", vocab=512, d_model=64, n_layers=2,
                        n_heads=2, d_ff=176, seq_len=64, batch=16),
    "s130m": ModelConfig("s130m", "130M", vocab=1024, d_model=96, n_layers=3,
                         n_heads=3, d_ff=256, seq_len=64, batch=16),
    "s350m": ModelConfig("s350m", "350M", vocab=2048, d_model=128, n_layers=4,
                         n_heads=4, d_ff=344, seq_len=96, batch=16),
    # e2e driver size (stands in for the 1B/7B rows)
    "e2e": ModelConfig("e2e", "1B/7B", vocab=4096, d_model=192, n_layers=4,
                       n_heads=4, d_ff=512, seq_len=128, batch=16),
    # App. F generality check (GPT2-style block)
    "gpt2s": ModelConfig("gpt2s", "GPT2-M", vocab=1024, d_model=96, n_layers=3,
                         n_heads=3, d_ff=384, seq_len=64, batch=16, arch="gpt2"),
}

# Real LLaMA dims for Appendix-B memory accounting (2-byte bf16 units).
# (vocab, d_model, n_layers, d_ff) per HF llama configs / the paper.
PAPER_DIMS = {
    "60M": dict(vocab=32000, d_model=512, n_layers=8, d_ff=1376),
    "130M": dict(vocab=32000, d_model=768, n_layers=12, d_ff=2048),
    "350M": dict(vocab=32000, d_model=1024, n_layers=24, d_ff=2736),
    "1B": dict(vocab=32000, d_model=2048, n_layers=24, d_ff=5461),
    "7B": dict(vocab=32000, d_model=4096, n_layers=32, d_ff=11008),
}

# Dims for the Table-1 normalization micro-benchmarks (paper: 1024/2048/
# 4096 on an A40; scaled to CPU but spanning the same 4x range).
NORM_BENCH_DIMS = (128, 256, 512)
