"""Build-time compile path (L1 kernels + L2 model/optimizers + AOT lowering).

Python in this package runs ONCE, at `make artifacts`; the Rust
coordinator loads the resulting HLO-text artifacts and never imports it.
"""
