//! Shared counting global allocator for the bench acceptance gates:
//! every heap allocation in the process bumps a counter, so
//! "zero allocations in the measured loop" is measured, not asserted by
//! eyeball. Each bench crate pulls this in via `#[path]` and declares
//! its own `#[global_allocator]` instance:
//!
//! ```ignore
//! #[path = "support/alloc_counter.rs"]
//! mod alloc_counter;
//! use alloc_counter::{allocs, CountingAlloc};
//!
//! #[global_allocator]
//! static GLOBAL: CountingAlloc = CountingAlloc;
//! ```
//!
//! Deallocations are deliberately not counted: the gates care about
//! allocation *pressure* per iteration, and a free-only imbalance cannot
//! occur in a loop that reuses its buffers.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Heap allocations observed so far, process-wide.
pub fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}
