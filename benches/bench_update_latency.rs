//! Optimizer-update latency bench: the cost of ONE `update_<opt>_<size>`
//! execution, isolated from fwd/bwd — the paper's Table 1 extended to
//! whole-optimizer updates (ablation bench from DESIGN.md §5).
//!
//!   cargo bench --bench bench_update_latency
//!
//! Expected shape: stateless/colnorm updates cheapest; Adam ~ elementwise
//! x3 state; Muon/SWAN pay the NS matmul tax; GaLore amortizes its
//! projector refresh (1/PROJ_REFRESH of steps).

use scale_llm::runtime::{Engine, Tensor};
use scale_llm::util::bench::Bencher;
use scale_llm::util::rng::Pcg;

fn run(engine: &Engine) -> anyhow::Result<()> {
    let size = "s130m";
    let info = engine.manifest.size(size)?.clone();
    let mut bench = Bencher::with_budget(2.0);
    println!("== update-step latency, {size} ({:.2}M params) ==", info.param_count as f64 / 1e6);

    let mut results = Vec::new();
    for opt in engine.manifest.optimizers_for(size) {
        let exe = engine.load(&format!("update_{opt}_{size}"))?;
        // params from init, zero state, random grads, fixed lr/step
        let params = engine.run(&format!("init_{size}"), &[Tensor::scalar_i32(0)])?;
        let state: Vec<Tensor> = engine
            .manifest
            .state_spec(&opt, size)?
            .iter()
            .map(|s| Tensor::zeros(&s.shape))
            .collect();
        let mut rng = Pcg::new(1);
        let grads: Vec<Tensor> = info
            .params
            .iter()
            .map(|p| {
                Tensor::from_f32(
                    &p.shape,
                    (0..p.numel()).map(|_| 0.01 * rng.normal() as f32).collect(),
                )
            })
            .collect();
        // assemble the update inputs by reference, exactly as the
        // trainer's hot path does — nothing is cloned per iteration
        let lr_t = Tensor::scalar_f32(1e-3);
        let step_t = Tensor::scalar_f32(2.0); // non-refresh step for GaLore
        let mut inputs: Vec<&Tensor> = Vec::new();
        inputs.extend(params.iter());
        inputs.extend(state.iter());
        inputs.extend(grads.iter());
        inputs.push(&lr_t);
        inputs.push(&step_t);
        let stats = bench.bench(&format!("update {opt}"), || {
            engine.run_exe_refs(&exe, &inputs).unwrap();
        });
        results.push((opt, stats.mean_ms()));
    }

    results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nranking (fastest first):");
    for (opt, ms) in results {
        println!("  {opt:<24} {ms:>8.3} ms");
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    match Engine::new("artifacts").and_then(|engine| run(&engine)) {
        Ok(()) => {}
        Err(e) => println!("skipping update-latency bench (artifacts/PJRT unavailable): {e}"),
    }
    Ok(())
}
