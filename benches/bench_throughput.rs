//! End-to-end training throughput on the native executor, with the
//! deterministic steady-state gates that seed the perf trajectory.
//!
//!   cargo bench --bench bench_throughput
//!
//! Sections:
//!   1. Executor steady state (gated): drive `fwd_bwd_tiny` and
//!      `update_scale_tiny` through `Engine::run_exe_refs_into` with
//!      reused output buffers and the parallel threshold pinned to the
//!      sequential path — the measured loop must perform ZERO heap
//!      allocations (the workspace-arena contract of `exec`).
//!   2. Attention pair dispatch A/B: per-config `fwd_bwd` latency with
//!      the per-(batch, head) attention fan-out forced sequential vs
//!      forced parallel (`exec::set_attn_pair_override`), everything
//!      else at the calibrated thresholds. Both paths are bit-identical;
//!      these rows record what the fan-out buys per config.
//!   3. Trainer throughput: tokens/sec and step-latency p50/p99 for
//!      1 vs N shards on the tiny and s60m configs — the measured loops
//!      must spawn ZERO threads (the persistent-pool contract).
//!   4. Serve decode (gated): steady-state KV-cache decode rounds
//!      through `serve::ServeEngine`, single-stream and batched, with
//!      non-greedy sampling so the sampler scratch is part of the
//!      audit — the measured rounds must allocate and spawn NOTHING.
//!
//! The gates are deterministic and enforced via the exit code (CI runs
//! this bench); the timing numbers are recorded in
//! `BENCH_throughput.json` for trajectory review, not gated — CI boxes
//! are too noisy for latency assertions. Headline rows — including the
//! per-optimizer `update_rule` latencies for the frontier family — also
//! append to `BENCH_history.json` via `util::bench::append_history`,
//! whose silent-empty guard fails the run rather than record a hollow
//! entry.

use std::time::{Duration, Instant};

use scale_llm::coordinator::{ddp, TrainOptions, Trainer};
use scale_llm::exec;
use scale_llm::mesh;
use scale_llm::parallel;
use scale_llm::runtime::{Engine, Tensor};
use scale_llm::serve::{Request, ServeEngine, ServeModel};
use scale_llm::util::bench::append_history;
use scale_llm::util::json::Json;

#[path = "support/alloc_counter.rs"]
mod alloc_counter;

use alloc_counter::{allocs, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Section 1: the executor's zero-allocation steady state. Returns
/// (fwd+upd allocations over the measured loop, fwd ms, upd ms).
/// The parallel threshold is pinned to the sequential path for the
/// duration: pool dispatch boxes its task closures by design, so the
/// allocation audit measures the arena contract, not the dispatch
/// bookkeeping (spawns are gated instead).
fn exec_steady_state(engine: &Engine) -> anyhow::Result<(u64, f64, f64)> {
    parallel::set_min_ops_override(Some(usize::MAX));
    let result = exec_steady_state_pinned(engine);
    parallel::set_min_ops_override(None); // restore even on error
    result
}

fn exec_steady_state_pinned(engine: &Engine) -> anyhow::Result<(u64, f64, f64)> {
    let info = engine.manifest.size("tiny")?.clone();
    let params = exec::native_init(&info, 0);
    let (mb, w) = (engine.manifest.microbatch, info.seq_len + 1);
    let toks: Vec<i32> = (0..mb * w).map(|i| (i % info.vocab) as i32).collect();
    let batch = Tensor::from_i32(&[mb, w], toks);
    let fwd = engine.load("fwd_bwd_tiny")?;
    let upd = engine.load("update_scale_tiny")?;
    let state: Vec<Tensor> = engine
        .manifest
        .state_spec("scale", "tiny")?
        .iter()
        .map(|s| Tensor::zeros(&s.shape))
        .collect();
    let lr_t = Tensor::scalar_f32(1e-2);
    let step_t = Tensor::scalar_f32(1.0);

    let mut fwd_inputs: Vec<&Tensor> = params.iter().collect();
    fwd_inputs.push(&batch);
    let mut fwd_out: Vec<Tensor> = Vec::new();
    engine.run_exe_refs_into(&fwd, &fwd_inputs, &mut fwd_out)?;
    engine.run_exe_refs_into(&fwd, &fwd_inputs, &mut fwd_out)?; // warm arena + outputs

    let iters = 20u32;
    let a0 = allocs();
    let t0 = Instant::now();
    for _ in 0..iters {
        engine.run_exe_refs_into(&fwd, &fwd_inputs, &mut fwd_out)?;
    }
    let fwd_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    let fwd_allocs = allocs() - a0;

    let mut upd_inputs: Vec<&Tensor> = params.iter().collect();
    upd_inputs.extend(state.iter());
    upd_inputs.extend(fwd_out[1..].iter());
    upd_inputs.push(&lr_t);
    upd_inputs.push(&step_t);
    let mut upd_out: Vec<Tensor> = Vec::new();
    engine.run_exe_refs_into(&upd, &upd_inputs, &mut upd_out)?;
    engine.run_exe_refs_into(&upd, &upd_inputs, &mut upd_out)?;

    let a1 = allocs();
    let t1 = Instant::now();
    for _ in 0..iters {
        engine.run_exe_refs_into(&upd, &upd_inputs, &mut upd_out)?;
    }
    let upd_ms = t1.elapsed().as_secs_f64() * 1e3 / iters as f64;
    let upd_allocs = allocs() - a1;

    // the Table-13 mix_* updates share the workspace-arena contract:
    // audit each with a short loop (allocations gated, time not kept)
    let mut mix_allocs = 0u64;
    for opt in [
        "mix_col_last_row_rest",
        "mix_row_first_col_rest",
        "mix_larger_dim",
        "mix_row_last_col_rest",
    ] {
        let name = format!("update_{opt}_tiny");
        if engine.manifest.artifact(&name).is_err() {
            continue; // an xla manifest may bound its artifact set
        }
        let exe = engine.load(&name)?;
        let state: Vec<Tensor> = engine
            .manifest
            .state_spec(opt, "tiny")?
            .iter()
            .map(|s| Tensor::zeros(&s.shape))
            .collect();
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.extend(state.iter());
        inputs.extend(fwd_out[1..].iter());
        inputs.push(&lr_t);
        inputs.push(&step_t);
        let mut out: Vec<Tensor> = Vec::new();
        engine.run_exe_refs_into(&exe, &inputs, &mut out)?;
        engine.run_exe_refs_into(&exe, &inputs, &mut out)?; // warm workspaces
        let a = allocs();
        for _ in 0..10 {
            engine.run_exe_refs_into(&exe, &inputs, &mut out)?;
        }
        mix_allocs += allocs() - a;
    }

    println!(
        "exec steady state: fwd {fwd_ms:.3} ms, update {upd_ms:.3} ms; \
         allocs over {iters}+{iters} iters: {} (must be 0); \
         mix_* update allocs: {mix_allocs} (must be 0)",
        fwd_allocs + upd_allocs
    );
    Ok((fwd_allocs + upd_allocs + mix_allocs, fwd_ms, upd_ms))
}

/// Section 2: attention-parallel vs sequential A/B on one config's
/// `fwd_bwd` executable. Restores the override even when a run errors.
fn attn_ab_row(engine: &Engine, size: &str) -> anyhow::Result<Json> {
    let result = attn_ab_row_forced(engine, size);
    exec::set_attn_pair_override(None); // restore even on error
    result
}

fn attn_ab_row_forced(engine: &Engine, size: &str) -> anyhow::Result<Json> {
    let info = engine.manifest.size(size)?.clone();
    let params = exec::native_init(&info, 0);
    let (mb, w) = (engine.manifest.microbatch, info.seq_len + 1);
    let toks: Vec<i32> = (0..mb * w).map(|i| (i % info.vocab) as i32).collect();
    let batch = Tensor::from_i32(&[mb, w], toks);
    let fwd = engine.load(&format!("fwd_bwd_{size}"))?;
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(&batch);
    let mut out: Vec<Tensor> = Vec::new();
    engine.run_exe_refs_into(&fwd, &inputs, &mut out)?; // warm arena + outputs
    let iters = 12u32;
    let mut ms = [0.0f64; 2];
    for (slot, force) in [(0usize, Some(false)), (1, Some(true))] {
        exec::set_attn_pair_override(force);
        engine.run_exe_refs_into(&fwd, &inputs, &mut out)?; // warm this path
        let t0 = Instant::now();
        for _ in 0..iters {
            engine.run_exe_refs_into(&fwd, &inputs, &mut out)?;
        }
        ms[slot] = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    }
    let speedup = ms[0] / ms[1].max(1e-9);
    println!(
        "{size}: fwd_bwd attn-sequential {:.3} ms, attn-parallel {:.3} ms ({speedup:.2}x)",
        ms[0], ms[1]
    );
    Ok(Json::obj(vec![
        ("size", Json::str(size)),
        ("fwd_bwd_attn_seq_ms", Json::num(ms[0])),
        ("fwd_bwd_attn_par_ms", Json::num(ms[1])),
        ("attn_parallel_speedup", Json::num(speedup)),
    ]))
}

/// Durability-tax audit: with no failpoint spec installed, a
/// `fault::fires` check must be one relaxed atomic load — zero heap
/// allocations and zero thread spawns across a million calls. (The
/// trainer hot path runs one per step; this gate keeps the injection
/// hooks free when disarmed.)
fn failpoint_disabled_audit() -> (u64, f64) {
    assert!(
        !scale_llm::fault::armed(),
        "throughput bench must run with failpoints disarmed"
    );
    let iters = 1_000_000u64;
    let spawned0 = parallel::threads_spawned();
    let a0 = allocs();
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(scale_llm::fault::fires(std::hint::black_box("grad_nan")));
    }
    let ns_per_call = t0.elapsed().as_secs_f64() * 1e9 / iters as f64;
    let violations = (allocs() - a0) + (parallel::threads_spawned() - spawned0) as u64;
    (violations, ns_per_call)
}

/// Mesh all-reduce latency: `mesh::reduce_ranks_into` over N synthetic
/// rank outputs on the shared pool. The template is restored by memcpy
/// *outside* the timed window each iteration (the reduction consumes
/// its inputs), and a one-off sanity check pins the delegation against
/// the sequential reference before anything is timed.
fn mesh_reduce_row(ranks: usize) -> Json {
    let pool = parallel::shared();
    let shapes: [&[usize]; 4] = [&[256, 256], &[256, 256], &[64, 256], &[256]];
    let template: Vec<Vec<Tensor>> = (0..ranks)
        .map(|r| {
            shapes
                .iter()
                .enumerate()
                .map(|(p, s)| {
                    let mut t = Tensor::zeros(s);
                    for (i, x) in t.f32s_mut().iter_mut().enumerate() {
                        *x = ((r * 37 + p * 11 + i) as f32).sin();
                    }
                    t
                })
                .collect()
        })
        .collect();

    let want = ddp::tree_all_reduce_sequential(template.clone());
    let mut outs = template.clone();
    mesh::reduce_ranks_into(pool, &mut outs, 0);
    for (p, w) in want.iter().enumerate() {
        assert_eq!(outs[0][p].f32s(), w.f32s(), "mesh reduce drifted from the reference");
    }

    let mut scratch = template.clone();
    let iters = 30u32;
    let mut total = Duration::ZERO;
    for _ in 0..iters {
        for (s, t) in scratch.iter_mut().flatten().zip(template.iter().flatten()) {
            s.f32s_mut().copy_from_slice(t.f32s());
        }
        let t0 = Instant::now();
        mesh::reduce_ranks_into(pool, &mut scratch, 0);
        total += t0.elapsed();
    }
    let ms = total.as_secs_f64() * 1e3 / iters as f64;
    println!("mesh_reduce x{ranks}: {ms:.4} ms/all-reduce");
    Json::obj(vec![("ranks", Json::num(ranks as f64)), ("reduce_ms", Json::num(ms))])
}

/// The row sections every history entry must carry, each non-empty —
/// `util::bench::append_history`'s silent-empty guard turns a run that
/// produced zero rows for any of them into a hard bench failure instead
/// of a hollow data point in the committed trajectory.
const HISTORY_ROW_KEYS: [&str; 4] =
    ["mesh_reduce", "serve_decode", "update_rule", "sharded_state_bytes"];

/// Per-optimizer `update_{opt}_tiny` latency rows for the history
/// trajectory: SCALE and Adam next to the frontier rules (partial
/// momentum, momentum-as-normalizer), allocation-audited like the mix_*
/// loop. As in section 1 the parallel threshold is pinned sequential so
/// the audit measures the workspace-arena contract, not pool dispatch.
fn update_rule_rows(engine: &Engine) -> anyhow::Result<(Vec<Json>, u64)> {
    parallel::set_min_ops_override(Some(usize::MAX));
    let result = update_rule_rows_pinned(engine);
    parallel::set_min_ops_override(None); // restore even on error
    result
}

fn update_rule_rows_pinned(engine: &Engine) -> anyhow::Result<(Vec<Json>, u64)> {
    let info = engine.manifest.size("tiny")?.clone();
    let params = exec::native_init(&info, 0);
    let (mb, w) = (engine.manifest.microbatch, info.seq_len + 1);
    let toks: Vec<i32> = (0..mb * w).map(|i| (i % info.vocab) as i32).collect();
    let batch = Tensor::from_i32(&[mb, w], toks);
    let fwd = engine.load("fwd_bwd_tiny")?;
    let mut fwd_inputs: Vec<&Tensor> = params.iter().collect();
    fwd_inputs.push(&batch);
    let mut fwd_out: Vec<Tensor> = Vec::new();
    engine.run_exe_refs_into(&fwd, &fwd_inputs, &mut fwd_out)?;
    let lr_t = Tensor::scalar_f32(1e-2);
    let step_t = Tensor::scalar_f32(1.0);
    let mut rows = Vec::new();
    let mut violations = 0u64;
    for opt in ["scale", "adam", "adapm_first_last", "adapm_top2", "adams"] {
        let name = format!("update_{opt}_tiny");
        if engine.manifest.artifact(&name).is_err() {
            continue; // an xla manifest may predate the frontier family
        }
        let exe = engine.load(&name)?;
        let state: Vec<Tensor> = engine
            .manifest
            .state_spec(opt, "tiny")?
            .iter()
            .map(|s| Tensor::zeros(&s.shape))
            .collect();
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.extend(state.iter());
        inputs.extend(fwd_out[1..].iter());
        inputs.push(&lr_t);
        inputs.push(&step_t);
        let mut out: Vec<Tensor> = Vec::new();
        engine.run_exe_refs_into(&exe, &inputs, &mut out)?;
        engine.run_exe_refs_into(&exe, &inputs, &mut out)?; // warm workspaces
        let iters = 15u32;
        let a = allocs();
        let t0 = Instant::now();
        for _ in 0..iters {
            engine.run_exe_refs_into(&exe, &inputs, &mut out)?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        violations += allocs() - a;
        println!("update_rule {opt}: {ms:.3} ms/step");
        rows.push(Json::obj(vec![
            ("size", Json::str("tiny")),
            ("optimizer", Json::str(opt)),
            ("update_ms", Json::num(ms)),
            ("state_slots", Json::num(state.len() as f64)),
        ]));
    }
    anyhow::ensure!(!rows.is_empty(), "no update_{{opt}}_tiny artifact was benchable");
    Ok((rows, violations))
}

/// Measured per-rank optimizer-state bytes under `--shard-state`, for
/// the history trajectory: the exact contiguous shard partition the
/// mesh uses, SCALE next to Adam at each rank count.
fn sharded_state_rows(engine: &Engine) -> Vec<Json> {
    let mut rows = Vec::new();
    for optimizer in ["scale", "adam"] {
        for ranks in [1usize, 2, 4] {
            let Ok(bytes) = scale_llm::memory::estimator::sharded_state_bytes(
                &engine.manifest,
                optimizer,
                "tiny",
                ranks,
            ) else {
                continue; // an xla manifest may not carry this optimizer
            };
            let peak = bytes.iter().copied().max().unwrap_or(0);
            rows.push(Json::obj(vec![
                ("size", Json::str("tiny")),
                ("optimizer", Json::str(optimizer)),
                ("ranks", Json::num(ranks as f64)),
                ("peak_rank_bytes", Json::num(peak as f64)),
                ("per_rank_bytes", Json::Arr(bytes.iter().map(|&b| Json::num(b as f64)).collect())),
            ]));
        }
    }
    rows
}

struct TrainRow {
    size: String,
    shards: usize,
    steps: usize,
    tokens_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
    allocs_per_step: f64,
    spawns: usize,
}

/// Section 3: full `Trainer::train_step` loop — throughput, latency
/// percentiles, per-step allocations (reported), thread spawns (gated).
fn train_row(engine: &Engine, size: &str, shards: usize, steps: usize) -> anyhow::Result<TrainRow> {
    let opts = TrainOptions {
        size: size.into(),
        optimizer: "scale".into(),
        // +2 so the metrics history reserved at construction also covers
        // the warm-up steps: the measured loop must never regrow it
        steps: steps + 2,
        base_lr: 1e-2,
        schedule: None,
        shards,
        seed: 0,
        eval_every: 0,
        eval_batches: 2,
        log_every: 0,
        quiet: true,
    };
    let mut tr = Trainer::new(engine, opts)?;
    tr.train_step()?; // warm: ring fill, arena + buffer creation
    tr.train_step()?;
    let mut samples: Vec<Duration> = Vec::with_capacity(steps);
    let spawned0 = parallel::threads_spawned();
    let a0 = allocs();
    let t0 = Instant::now();
    for _ in 0..steps {
        let s0 = Instant::now();
        tr.train_step()?;
        samples.push(s0.elapsed());
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let allocs_per_step = (allocs() - a0) as f64 / steps as f64;
    let spawns = parallel::threads_spawned() - spawned0;
    samples.sort();
    let p50 = samples[steps / 2].as_secs_f64() * 1e3;
    let p99 = samples[(steps * 99 / 100).min(steps - 1)].as_secs_f64() * 1e3;
    let tokens = (steps * shards.max(1) * tr.microbatch * tr.seq_len) as f64;
    let row = TrainRow {
        size: size.to_string(),
        shards,
        steps,
        tokens_per_sec: tokens / elapsed,
        p50_ms: p50,
        p99_ms: p99,
        allocs_per_step,
        spawns,
    };
    println!(
        "{size} x{shards}: {:.0} tok/s, p50 {:.3} ms, p99 {:.3} ms, \
         {:.1} allocs/step, {} spawns",
        row.tokens_per_sec, row.p50_ms, row.p99_ms, row.allocs_per_step, row.spawns
    );
    Ok(row)
}

struct DecodeRow {
    streams: usize,
    tokens_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
    allocs: u64,
    spawns: usize,
}

/// Section 4: the serve decode loop. As in section 1, the parallel
/// threshold is pinned to the sequential path for the audit window —
/// pool dispatch boxes its task closures by design, so the allocation
/// gate measures the KV-slab/workspace contract, not dispatch
/// bookkeeping (spawns are gated separately). Sampling runs non-greedy
/// (temperature + top-k + top-p) so the sampler's reused scratch is
/// inside the audit.
fn decode_row(model: &ServeModel, streams: usize) -> anyhow::Result<DecodeRow> {
    parallel::set_min_ops_override(Some(usize::MAX));
    let result = decode_row_pinned(model, streams);
    parallel::set_min_ops_override(None); // restore even on error
    result
}

fn decode_row_pinned(model: &ServeModel, streams: usize) -> anyhow::Result<DecodeRow> {
    let mut engine = ServeEngine::new(model, streams);
    // budget sized so no stream retires inside the measured window
    let budget = model.max_seq() - 3;
    for i in 0..streams {
        let req = Request {
            id: format!("s{i}"),
            prompt: vec![1, 2, 3],
            max_new: budget,
            temperature: 0.7,
            top_k: 8,
            top_p: 0.9,
            seed: i as u64,
            deadline_ms: 0,
        };
        engine.submit(req).map_err(|e| anyhow::anyhow!("bench submit: {e}"))?;
    }
    engine.step(); // admission: prefill + first sampled token
    engine.step(); // one warm decode round
    let measured = 8usize.min(budget.saturating_sub(3));
    anyhow::ensure!(measured > 0, "context too short for a measured decode window");
    let mut samples: Vec<Duration> = Vec::with_capacity(measured);
    let spawned0 = parallel::threads_spawned();
    let a0 = allocs();
    let t0 = Instant::now();
    for _ in 0..measured {
        let s0 = Instant::now();
        let produced = engine.step();
        anyhow::ensure!(produced == streams, "stream retired mid-measurement");
        samples.push(s0.elapsed());
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let decode_allocs = allocs() - a0;
    let spawns = parallel::threads_spawned() - spawned0;
    while !engine.idle() {
        engine.step();
    }
    anyhow::ensure!(
        engine.take_finished().len() == streams,
        "decode bench streams failed to finish"
    );
    samples.sort();
    let p50 = samples[measured / 2].as_secs_f64() * 1e3;
    let p99 = samples[(measured * 99 / 100).min(measured - 1)].as_secs_f64() * 1e3;
    let row = DecodeRow {
        streams,
        tokens_per_sec: (measured * streams) as f64 / elapsed,
        p50_ms: p50,
        p99_ms: p99,
        allocs: decode_allocs,
        spawns,
    };
    println!(
        "decode x{streams}: {:.0} tok/s, token p50 {:.3} ms, p99 {:.3} ms, \
         {} allocs, {} spawns",
        row.tokens_per_sec, row.p50_ms, row.p99_ms, row.allocs, row.spawns
    );
    Ok(row)
}

fn unix_time() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

fn main() -> anyhow::Result<()> {
    // touch the shared pool (and its calibration) up front so one-time
    // thread spawns and the probe are outside every measured region
    let _ = parallel::shared();
    let _ = parallel::tuned_min_ops();
    let engine = match Engine::new("artifacts") {
        Ok(e) if e.manifest.sizes.contains_key("tiny") => e,
        Ok(_) => {
            println!("skipping throughput bench (manifest lacks the tiny smoke size)");
            return Ok(());
        }
        Err(e) => {
            println!("skipping throughput bench (engine unavailable): {e}");
            return Ok(());
        }
    };
    println!("platform: {}", engine.platform());

    println!("\n== executor steady state (zero-alloc gate) ==");
    let (exec_allocs, fwd_ms, upd_ms) = exec_steady_state(&engine)?;

    println!("\n== disarmed failpoint overhead (zero-alloc gate) ==");
    let (fp_violations, fp_ns) = failpoint_disabled_audit();
    println!(
        "fault::fires with no spec installed: {fp_ns:.2} ns/call, \
         {fp_violations} allocs+spawns over 1M calls (must be 0)"
    );

    println!("\n== attention pair dispatch A/B (calibrated thresholds) ==");
    let attn_rows = vec![attn_ab_row(&engine, "tiny")?, attn_ab_row(&engine, "s60m")?];

    println!("\n== update-rule latency (zero-alloc gate) ==");
    let (upd_rule_rows, upd_rule_allocs) = update_rule_rows(&engine)?;

    println!("\n== mesh all-reduce latency ==");
    let mesh_rows = vec![mesh_reduce_row(2), mesh_reduce_row(4)];

    println!("\n== trainer throughput (zero-spawn gate) ==");
    let rows = vec![
        train_row(&engine, "tiny", 1, 60)?,
        train_row(&engine, "tiny", 4, 60)?,
        train_row(&engine, "s60m", 1, 30)?,
        train_row(&engine, "s60m", 4, 30)?,
    ];
    let total_spawns: usize = rows.iter().map(|r| r.spawns).sum();

    println!("\n== serve decode (zero-alloc + zero-spawn gate) ==");
    let smodel = ServeModel::init("tiny", 0)?;
    let decode_rows = vec![decode_row(&smodel, 1)?, decode_row(&smodel, 4)?];
    let decode_violations: u64 = decode_rows.iter().map(|r| r.allocs + r.spawns as u64).sum();
    let decode_json: Vec<Json> = decode_rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("streams", Json::num(r.streams as f64)),
                ("tokens_per_sec", Json::num(r.tokens_per_sec)),
                ("token_p50_ms", Json::num(r.p50_ms)),
                ("token_p99_ms", Json::num(r.p99_ms)),
                ("allocs", Json::num(r.allocs as f64)),
                ("spawns", Json::num(r.spawns as f64)),
            ])
        })
        .collect();

    let row_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("size", Json::str(&r.size)),
                ("shards", Json::num(r.shards as f64)),
                ("steps", Json::num(r.steps as f64)),
                ("tokens_per_sec", Json::num(r.tokens_per_sec)),
                ("step_p50_ms", Json::num(r.p50_ms)),
                ("step_p99_ms", Json::num(r.p99_ms)),
                ("allocs_per_step", Json::num(r.allocs_per_step)),
                ("spawns", Json::num(r.spawns as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("throughput")),
        ("platform", Json::str(&engine.platform())),
        ("exec_fwd_ms", Json::num(fwd_ms)),
        ("exec_update_ms", Json::num(upd_ms)),
        ("exec_steady_allocs", Json::num(exec_allocs as f64)),
        ("failpoint_check_ns", Json::num(fp_ns)),
        ("failpoint_disabled_allocs", Json::num(fp_violations as f64)),
        ("train_spawns", Json::num(total_spawns as f64)),
        ("attention_ab", Json::Arr(attn_rows)),
        ("update_rule", Json::Arr(upd_rule_rows.clone())),
        ("mesh_reduce", Json::Arr(mesh_rows.clone())),
        ("serve_decode", Json::Arr(decode_json.clone())),
        ("rows", Json::Arr(row_json)),
    ]);
    std::fs::write("BENCH_throughput.json", doc.to_string())?;
    println!("\nbench json -> BENCH_throughput.json");
    append_history(
        "BENCH_history.json",
        Json::obj(vec![
            ("bench", Json::str("throughput")),
            ("platform", Json::str(&engine.platform())),
            ("unix_time", Json::num(unix_time())),
            ("exec_fwd_ms", Json::num(fwd_ms)),
            ("exec_update_ms", Json::num(upd_ms)),
            ("update_rule", Json::Arr(upd_rule_rows)),
            ("mesh_reduce", Json::Arr(mesh_rows)),
            ("serve_decode", Json::Arr(decode_json)),
            ("sharded_state_bytes", Json::Arr(sharded_state_rows(&engine))),
        ]),
        &HISTORY_ROW_KEYS,
    )?;

    println!("\n== acceptance gates ==");
    println!(
        "  executor steady state allocation-free: {} ({exec_allocs} allocs)",
        if exec_allocs == 0 { "PASS" } else { "FAIL" }
    );
    println!(
        "  zero thread spawns across training loops: {} ({total_spawns} spawned)",
        if total_spawns == 0 { "PASS" } else { "FAIL" }
    );
    println!(
        "  disarmed failpoints allocation- and spawn-free: {} ({fp_violations})",
        if fp_violations == 0 { "PASS" } else { "FAIL" }
    );
    println!(
        "  serve decode loop allocation- and spawn-free: {} ({decode_violations})",
        if decode_violations == 0 { "PASS" } else { "FAIL" }
    );
    println!(
        "  update-rule rows allocation-free: {} ({upd_rule_allocs} allocs)",
        if upd_rule_allocs == 0 { "PASS" } else { "FAIL" }
    );
    anyhow::ensure!(
        exec_allocs == 0,
        "steady-state executor performed {exec_allocs} heap allocations (expected 0)"
    );
    anyhow::ensure!(
        total_spawns == 0,
        "training loops spawned {total_spawns} threads (expected 0)"
    );
    anyhow::ensure!(
        fp_violations == 0,
        "disarmed failpoint checks performed {fp_violations} allocations/spawns (expected 0)"
    );
    anyhow::ensure!(
        decode_violations == 0,
        "serve decode rounds performed {decode_violations} allocations/spawns (expected 0)"
    );
    anyhow::ensure!(
        upd_rule_allocs == 0,
        "update-rule latency loops performed {upd_rule_allocs} heap allocations (expected 0)"
    );
    Ok(())
}
