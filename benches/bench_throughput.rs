//! Table 7 bench: end-to-end training throughput (tokens/sec) per
//! optimizer.
//!
//!   cargo bench --bench bench_throughput
//!
//! Paper (LLaMA 1B, 4xH100): SCALE ~ Adam ~ APOLLO ~ Stable-SPAM;
//! NS-based methods (Muon/SWAN) ~18.5% slower; GaLore/Fira ~8% slower.
//! The measured column must reproduce that *shape*: NS methods pay the
//! orthogonalization tax, SCALE stays within a few % of Adam.

use scale_llm::harness::tables::table7;
use scale_llm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // ~20 steps per optimizer is enough for a stable tokens/sec estimate
    match Engine::new("artifacts").and_then(|engine| table7(&engine, "s130m", 20)) {
        Ok(t) => println!("{t}"),
        Err(e) => println!("skipping throughput bench (artifacts/PJRT unavailable): {e}"),
    }
    Ok(())
}
