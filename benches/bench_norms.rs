//! Table 1 bench: wall-clock of each gradient normalization vs dim.
//!
//!   cargo bench --bench bench_norms
//!
//! Paper (A40 GPU, d=1024/2048/4096): sign < row ~ col << NS << exact SVD.
//! Here (1-core CPU PJRT, manifest dims): the same ordering must hold;
//! exact SVD is unavailable (LAPACK custom-calls) — NS is the paper's
//! production path anyway.
//!
//! The native section runs both API tiers — allocating wrappers and the
//! zero-copy `_into` kernels — and always executes, even without the
//! PJRT artifacts; results land in `BENCH_norms.json`.

use scale_llm::harness::tables::table1;
use scale_llm::optim::colnorm::{self, NormWorkspace};
use scale_llm::runtime::Engine;
use scale_llm::util::bench::{black_box, Bencher};
use scale_llm::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // native-Rust reference normalizations, to separate PJRT dispatch
    // overhead from the arithmetic itself. Dims come from the manifest
    // when artifacts exist so the native and PJRT sections compare at
    // identical sizes; otherwise the paper's d=1024/2048.
    let engine = Engine::new("artifacts").ok();
    let dims: Vec<usize> = engine
        .as_ref()
        .map(|e| e.manifest.norm_bench_dims.clone())
        .unwrap_or_else(|| vec![1024, 2048]);
    println!("== native Rust normalization (no PJRT dispatch) ==");
    let mut b = Bencher::with_budget(1.0);
    for &d in &dims {
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        b.bench(&format!("native col d={d}"), || {
            black_box(colnorm::colnorm(&g, d, d));
        });
        let mut ws = NormWorkspace::with_capacity(d);
        let mut out = vec![0.0f32; d * d];
        b.bench(&format!("native col_into d={d}"), || {
            colnorm::colnorm_into(&g, d, d, &mut ws, &mut out);
            black_box(out.len());
        });
        b.bench(&format!("native row d={d}"), || {
            black_box(colnorm::rownorm(&g, d, d));
        });
        b.bench(&format!("native row_into d={d}"), || {
            colnorm::rownorm_into(&g, d, d, &mut out);
            black_box(out.len());
        });
        b.bench(&format!("native sign d={d}"), || {
            black_box(colnorm::sign(&g));
        });
        b.bench(&format!("native sign_into d={d}"), || {
            colnorm::sign_into(&g, &mut out);
            black_box(out.len());
        });
    }
    b.write_json("BENCH_norms.json", "norms", vec![])?;

    // PJRT-lowered kernels (Table 1) — needs `make artifacts` + a real
    // PJRT backend (--features xla)
    match engine
        .ok_or_else(|| anyhow::anyhow!("artifacts unavailable"))
        .and_then(|engine| table1(&engine, 2.0))
    {
        Ok(t) => println!("{t}"),
        Err(e) => println!("\nskipping PJRT Table 1 section: {e}"),
    }
    Ok(())
}
