//! Table 1 bench: wall-clock of each gradient normalization vs dim.
//!
//!   cargo bench --bench bench_norms
//!
//! Paper (A40 GPU, d=1024/2048/4096): sign < row ~ col << NS << exact SVD.
//! Here (1-core CPU PJRT, manifest dims): the same ordering must hold;
//! exact SVD is unavailable (LAPACK custom-calls) — NS is the paper's
//! production path anyway.

use scale_llm::harness::tables::table1;
use scale_llm::optim::colnorm;
use scale_llm::runtime::Engine;
use scale_llm::util::bench::{black_box, Bencher};
use scale_llm::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("{}", table1(&engine, 2.0)?);

    // native-Rust reference normalizations at the same dims, to separate
    // PJRT dispatch overhead from the arithmetic itself
    println!("== native Rust normalization (no PJRT dispatch) ==");
    let mut b = Bencher::with_budget(1.0);
    for &d in &engine.manifest.norm_bench_dims {
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        b.bench(&format!("native col d={d}"), || {
            black_box(colnorm::colnorm(&g, d, d));
        });
        b.bench(&format!("native row d={d}"), || {
            black_box(colnorm::rownorm(&g, d, d));
        });
        b.bench(&format!("native sign d={d}"), || {
            black_box(colnorm::sign(&g));
        });
    }
    Ok(())
}
