//! L3 coordinator substrate bench: the non-PJRT parts of the hot loop —
//! corpus generation, BPE encoding, batching, tensor<->literal
//! conversion, tree all-reduce. The perf target (DESIGN.md §9) is that
//! these stay well under the PJRT execute time, i.e. the coordinator is
//! not the bottleneck (the paper's optimizer IS the cheap part).
//!
//!   cargo bench --bench bench_runtime

use scale_llm::coordinator::ddp::tree_all_reduce;
use scale_llm::data::{pipeline, Batcher};
use scale_llm::runtime::{Engine, Tensor};
use scale_llm::util::bench::{black_box, Bencher};
use scale_llm::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::with_budget(1.5);

    println!("== data pipeline ==");
    let (corpus, tok) = pipeline(1024, 0);
    b.bench("corpus.text 8KiB", || {
        black_box(corpus.text(8192, 1));
    });
    let text = corpus.text(8192, 2);
    b.bench("bpe encode 8KiB", || {
        black_box(tok.encode(&text));
    });
    let mut batcher = Batcher::new(&corpus, &tok, 1024, 4);
    b.bench_throughput("batcher [B=4,S=64]", 4.0 * 64.0, || {
        black_box(batcher.next_batch(0, 4, 64));
    });

    println!("\n== gradient plumbing (s130m-like tensor set) ==");
    // shapes mirror the s130m family closely enough for plumbing costs;
    // no manifest needed so this section always runs
    let shapes: Vec<Vec<usize>> = vec![
        vec![1024, 512],
        vec![512, 512],
        vec![512, 2048],
        vec![2048, 512],
        vec![512, 1024],
        vec![512],
    ];
    let mut rng = Pcg::new(5);
    let grads: Vec<Tensor> = shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            Tensor::from_f32(s, (0..n).map(|_| rng.normal() as f32).collect())
        })
        .collect();
    let total_mb = 4.0 * grads.iter().map(|t| t.numel()).sum::<usize>() as f64 / 1e6;
    b.bench(&format!("tree all-reduce x4 ({total_mb:.1} MB)"), || {
        let shards = vec![grads.clone(), grads.clone(), grads.clone(), grads.clone()];
        black_box(tree_all_reduce(shards));
    });
    b.bench("tensor -> literal (full param set)", || {
        for g in &grads {
            black_box(g.to_literal().unwrap());
        }
    });
    b.bench("tensor -> literal view (borrowed)", || {
        // the run_exe_refs input path: on the stub backend this aliases
        // the tensor storage instead of copying it
        for g in &grads {
            black_box(g.as_literal_ref().unwrap());
        }
    });

    println!("\n== PJRT dispatch floor ==");
    let floor = Engine::new("artifacts").and_then(|engine| {
        let d = engine.manifest.norm_bench_dims[0];
        let exe = engine.load(&format!("norm_sign_{d}"))?;
        let x = Tensor::zeros(&[d, d]);
        b.bench(&format!("execute norm_sign_{d} (dispatch floor)"), || {
            engine.run_exe(&exe, std::slice::from_ref(&x)).unwrap();
        });
        Ok(())
    });
    if let Err(e) = floor {
        println!("skipping (artifacts/PJRT unavailable): {e}");
    }

    println!(
        "\ncoordinator overhead target: each row above << one fwd_bwd step (see bench_throughput)"
    );
    Ok(())
}
