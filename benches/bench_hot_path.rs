//! Hot-path bench: per-step latency and heap allocations of the native
//! optimizer update (colnorm + last-layer momentum + tree all-reduce),
//! allocating baseline vs zero-copy path, at d=1024/2048.
//!
//!   cargo bench --bench bench_hot_path
//!
//! The baseline reproduces the pre-zero-copy semantics faithfully: the
//! per-step params/state clones the old `Trainer::train_step` performed,
//! the `to_vec` copy the old `Tensor::add_assign` made per reduce leg,
//! and the direction buffers the allocating `colnorm`/`scale_momentum`
//! materialize. The zero-copy path is what the trainer runs today:
//! in-place parallel `tree_all_reduce` + `scale_momentum_ws` through a
//! reusable `NormWorkspace`.
//!
//! A second section compares the persistent `WorkerPool` against the
//! old per-step `std::thread::scope` dispatch and the column-tiled
//! `_par` kernels against their sequential forms, recorded in
//! `BENCH_pool.json`.
//!
//! Acceptance gates printed at the end and recorded in the JSON
//! artifacts: the kernel inner loop performs ZERO heap allocations per
//! iteration, the pool spawns ZERO threads across the measured runs,
//! and the zero-copy step is >= 2x faster than the allocating baseline.

use scale_llm::coordinator::ddp;
use scale_llm::optim::colnorm::{
    colnorm, colnorm_into, colnorm_into_par_with, rownorm_into, sign_into, NormWorkspace,
};
use scale_llm::optim::rules::{scale_momentum_ws, scale_momentum_ws_par_with};
use scale_llm::parallel::{self, WorkerPool};
use scale_llm::runtime::Tensor;
use scale_llm::util::bench::{black_box, Bencher, Stats};
use scale_llm::util::json::Json;
use scale_llm::util::rng::Pcg;

#[path = "support/alloc_counter.rs"]
mod alloc_counter;

use alloc_counter::{allocs, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The old `Tensor::add_assign` semantics: copy the source slice, then
/// add — one full extra pass + allocation per reduce leg.
fn copy_add_reduce(mut shards: Vec<Vec<Tensor>>) -> Vec<Tensor> {
    let n = shards.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (left, right) = shards.split_at_mut(i + stride);
            for (d, s) in left[i].iter_mut().zip(right[0].iter()) {
                let copy = s.f32s().to_vec();
                for (a, b) in d.f32s_mut().iter_mut().zip(copy) {
                    *a += b;
                }
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    let mut out = shards.swap_remove(0);
    let inv = 1.0 / n as f32;
    for t in out.iter_mut() {
        t.scale(inv);
    }
    out
}

/// The pre-workspace `scale_momentum`: EMA pass, then an allocating
/// colnorm (norm scratch + full direction buffer), then the apply.
fn scale_momentum_alloc(
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    beta: f32,
) {
    for (mi, gi) in m.iter_mut().zip(g) {
        *mi = beta * *mi + (1.0 - beta) * gi;
    }
    let dir = colnorm(m, d_in, d_out);
    for (pi, di) in p.iter_mut().zip(dir) {
        *pi -= lr * di;
    }
}

struct DimOutcome {
    d: usize,
    baseline_ms: f64,
    fast_ms: f64,
    speedup: f64,
    kernel_allocs: u64,
    fast_step_allocs_per_iter: f64,
}

fn bench_dim(bench: &mut Bencher, d: usize, shards: usize) -> DimOutcome {
    let mut rng = Pcg::new(11);
    let n = d * d;
    let gen = |rng: &mut Pcg| -> Vec<f32> { (0..n).map(|_| 0.1 * rng.normal() as f32).collect() };

    // the "model": one lm_head-shaped matrix + momentum, plus per-shard
    // gradients as the fwd/bwd legs would hand them over
    let p0 = gen(&mut rng);
    let m0 = vec![0.0f32; n];
    let shard_grads: Vec<Vec<Tensor>> = (0..shards)
        .map(|_| vec![Tensor::from_f32(&[d, d], gen(&mut rng))])
        .collect();
    let (lr, beta) = (1e-2f32, 0.9f32);

    // ---- allocating baseline: old add_assign copies, old per-step
    // params/state clones, allocating colnorm direction buffer
    let mut p = p0.clone();
    let mut m = m0.clone();
    let base_stats = bench.bench(&format!("baseline alloc step d={d}"), || {
        // the old grad_step cloned the full param set per shard just to
        // assemble executable inputs
        for _ in 0..shards {
            black_box(p.clone());
        }
        let shards_in = shard_grads.clone();
        let reduced = copy_add_reduce(shards_in);
        let mut p_next = p.clone(); // the old trainer's params.clone()
        let mut m_next = m.clone(); // ... and state.clone()
        scale_momentum_alloc(&mut p_next, &mut m_next, reduced[0].f32s(), d, d, lr, beta);
        p = p_next;
        m = m_next;
        black_box(p.len());
    });

    // ---- zero-copy path: in-place parallel reduce + workspace rule
    let mut p = p0.clone();
    let mut m = m0.clone();
    let mut ws = NormWorkspace::with_capacity(d);
    // warm the workspace so steady-state is measured
    scale_momentum_ws(&mut p, &mut m, shard_grads[0][0].f32s(), d, d, 0.0, beta, &mut ws);
    let before_fast = allocs();
    let fast_stats = bench.bench(&format!("zero-copy step d={d}"), || {
        let shards_in = shard_grads.clone(); // stands in for fresh fwd/bwd outputs
        let reduced = ddp::tree_all_reduce(shards_in);
        scale_momentum_ws(&mut p, &mut m, reduced[0].f32s(), d, d, lr, beta, &mut ws);
        black_box(p.len());
    });
    let fast_iters = fast_stats.samples.max(1) as f64;
    let fast_step_allocs_per_iter = (allocs() - before_fast) as f64 / fast_iters;

    // ---- kernel-inner-loop allocation audit: with a warm workspace and
    // caller-owned buffers, the normalization/update kernels must not
    // touch the heap at all
    let g = shard_grads[0][0].f32s();
    let mut out = vec![0.0f32; n];
    colnorm_into(g, d, d, &mut ws, &mut out); // warm `out`'s page table too
    let before_kernel = allocs();
    for _ in 0..10 {
        colnorm_into(g, d, d, &mut ws, &mut out);
        rownorm_into(g, d, d, &mut out);
        sign_into(g, &mut out);
        scale_momentum_ws(&mut p, &mut m, g, d, d, lr, beta, &mut ws);
    }
    let kernel_allocs = allocs() - before_kernel;
    black_box(out.len());

    let speedup = base_stats.mean.as_secs_f64() / fast_stats.mean.as_secs_f64().max(1e-12);
    println!(
        "d={d}: baseline {:.3} ms, zero-copy {:.3} ms -> {speedup:.2}x; \
         kernel allocs over 10 iters: {kernel_allocs}",
        base_stats.mean_ms(),
        fast_stats.mean_ms(),
    );
    DimOutcome {
        d,
        baseline_ms: base_stats.mean_ms(),
        fast_ms: fast_stats.mean_ms(),
        speedup,
        kernel_allocs,
        fast_step_allocs_per_iter,
    }
}

/// Pooled vs per-step scoped-spawn dispatch, plus the tiled `_par`
/// kernels vs their sequential forms. Writes `BENCH_pool.json` and
/// returns the deterministic gate: pool worker spawns observed during
/// the measured loops (must be zero).
struct PoolOutcome {
    pooled: Stats,
    scoped: Stats,
    dispatch_speedup: f64,
    colnorm_speedup: f64,
    momentum_speedup: f64,
    spawns_during_runs: usize,
}

fn bench_pool(bench: &mut Bencher) -> PoolOutcome {
    let workers = 4usize;
    let tasks_n = 8usize;
    let pool = WorkerPool::new(workers);
    let mut rng = Pcg::new(7);
    // small per-task payload: dispatch overhead dominates, which is the
    // regime where per-step thread spawns hurt the most
    let payloads: Vec<Vec<f32>> = (0..tasks_n)
        .map(|_| (0..4096).map(|_| rng.normal() as f32).collect())
        .collect();

    let dot = |xs: &[f32]| xs.iter().map(|x| x * x).sum::<f32>();

    // warm the pool so steady-state dispatch is measured
    let _ = pool.run(payloads.iter().map(|p| move || dot(p)).collect::<Vec<_>>());
    let spawned_before = parallel::threads_spawned();
    let pooled = bench.bench(&format!("pool dispatch ({tasks_n} tasks)"), || {
        let sums = pool.run(payloads.iter().map(|p| move || dot(p)).collect::<Vec<_>>());
        black_box(sums.len());
    });
    let scoped = bench.bench(&format!("scoped spawn ({tasks_n} tasks)"), || {
        // the pre-pool per-step pattern: spawn, run, join, every call
        let sums: Vec<f32> = std::thread::scope(|scope| {
            let handles: Vec<_> = payloads
                .iter()
                .map(|p| scope.spawn(move || dot(p)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        black_box(sums.len());
    });

    // tiled kernels vs sequential at an lm_head-like size (d x 4d)
    let (di, dn) = (1024usize, 4096usize);
    let g: Vec<f32> = (0..di * dn).map(|_| 0.1 * rng.normal() as f32).collect();
    let mut out = vec![0.0f32; di * dn];
    let mut ws = NormWorkspace::with_capacity(dn);
    colnorm_into(&g, di, dn, &mut ws, &mut out); // warm pages
    let seq = bench.bench("colnorm sequential 1024x4096", || {
        colnorm_into(&g, di, dn, &mut ws, &mut out);
        black_box(out.len());
    });
    let par = bench.bench("colnorm tiled (pool) 1024x4096", || {
        colnorm_into_par_with(&pool, &g, di, dn, &mut ws, &mut out, 0);
        black_box(out.len());
    });
    let colnorm_speedup = seq.mean.as_secs_f64() / par.mean.as_secs_f64().max(1e-12);

    let mut p = vec![0.0f32; di * dn];
    let mut m = vec![0.0f32; di * dn];
    let seq_m = bench.bench("scale_momentum_ws sequential 1024x4096", || {
        scale_momentum_ws(&mut p, &mut m, &g, di, dn, 1e-3, 0.9, &mut ws);
        black_box(p.len());
    });
    let par_m = bench.bench("scale_momentum_ws tiled (pool) 1024x4096", || {
        scale_momentum_ws_par_with(&pool, &mut p, &mut m, &g, di, dn, 1e-3, 0.9, &mut ws, 0);
        black_box(p.len());
    });
    let momentum_speedup = seq_m.mean.as_secs_f64() / par_m.mean.as_secs_f64().max(1e-12);

    let spawns_during_runs = parallel::threads_spawned() - spawned_before;
    let dispatch_speedup = scoped.mean.as_secs_f64() / pooled.mean.as_secs_f64().max(1e-12);
    println!(
        "pool dispatch {:.1}x vs scoped spawn; colnorm par {colnorm_speedup:.2}x, \
         momentum par {momentum_speedup:.2}x; pool spawns during measured runs: \
         {spawns_during_runs}",
        dispatch_speedup
    );
    PoolOutcome {
        pooled,
        scoped,
        dispatch_speedup,
        colnorm_speedup,
        momentum_speedup,
        spawns_during_runs,
    }
}

fn main() -> anyhow::Result<()> {
    let shards = 4;
    println!("== optimizer hot path: allocating baseline vs zero-copy ({shards} shards) ==");
    // touch the shared pool up front so its one-time thread spawns are
    // outside every measured (and alloc-audited) region
    let _ = parallel::shared();
    let mut bench = Bencher::with_budget(2.0);
    let outcomes: Vec<DimOutcome> = [1024usize, 2048]
        .iter()
        .map(|&d| bench_dim(&mut bench, d, shards))
        .collect();

    println!("\n== persistent pool vs per-step scoped spawns ==");
    let mut pool_bench = Bencher::with_budget(1.5);
    let pool_outcome = bench_pool(&mut pool_bench);
    pool_bench.write_json(
        "BENCH_pool.json",
        "pool",
        vec![
            ("pooled_dispatch_ms", Json::num(pool_outcome.pooled.mean_ms())),
            ("scoped_dispatch_ms", Json::num(pool_outcome.scoped.mean_ms())),
            ("dispatch_speedup", Json::num(pool_outcome.dispatch_speedup)),
            ("colnorm_par_speedup", Json::num(pool_outcome.colnorm_speedup)),
            (
                "momentum_par_speedup",
                Json::num(pool_outcome.momentum_speedup),
            ),
            (
                "spawns_during_runs",
                Json::num(pool_outcome.spawns_during_runs as f64),
            ),
        ],
    )?;

    let mut extra: Vec<(&str, Json)> = Vec::new();
    let mut dims = Vec::new();
    for o in &outcomes {
        dims.push(Json::obj(vec![
            ("d", Json::num(o.d as f64)),
            ("baseline_ms", Json::num(o.baseline_ms)),
            ("zero_copy_ms", Json::num(o.fast_ms)),
            ("speedup", Json::num(o.speedup)),
            ("kernel_allocs_10_iters", Json::num(o.kernel_allocs as f64)),
            (
                "full_step_allocs_per_iter",
                Json::num(o.fast_step_allocs_per_iter),
            ),
        ]));
    }
    extra.push(("dims", Json::Arr(dims)));
    let min_speedup = outcomes.iter().map(|o| o.speedup).fold(f64::INFINITY, f64::min);
    let kernel_alloc_total: u64 = outcomes.iter().map(|o| o.kernel_allocs).sum();
    extra.push(("min_speedup", Json::num(min_speedup)));
    extra.push(("kernel_allocs_total", Json::num(kernel_alloc_total as f64)));
    bench.write_json("BENCH_hot_path.json", "hot_path", extra)?;

    println!("\n== acceptance gates ==");
    println!(
        "  kernel inner loop allocation-free: {} (total {kernel_alloc_total})",
        if kernel_alloc_total == 0 { "PASS" } else { "FAIL" }
    );
    println!(
        "  zero-copy >= 2x over allocating baseline: {} (min {min_speedup:.2}x)",
        if min_speedup >= 2.0 { "PASS" } else { "FAIL" }
    );
    println!(
        "  zero pool spawns across measured runs: {} ({} spawned)",
        if pool_outcome.spawns_during_runs == 0 {
            "PASS"
        } else {
            "FAIL"
        },
        pool_outcome.spawns_during_runs
    );
    // the allocation and spawn gates are deterministic — enforce them
    // with the exit code so a reintroduced per-iteration allocation or a
    // per-step thread spawn fails loudly. The speedup gates are
    // timing-dependent (CI machines vary), so they are recorded in the
    // JSON artifacts for trajectory review instead of failing the
    // process on a noisy box.
    anyhow::ensure!(
        kernel_alloc_total == 0,
        "kernel inner loop performed {kernel_alloc_total} heap allocations (expected 0)"
    );
    anyhow::ensure!(
        pool_outcome.spawns_during_runs == 0,
        "worker pool spawned {} threads during measured runs (expected 0)",
        pool_outcome.spawns_during_runs
    );
    Ok(())
}
