//! Minimal offline mirror of the `anyhow` API surface used by this repo.
//!
//! The build environment has no crates.io access (DESIGN.md §3), so the
//! workspace vendors exactly the subset it consumes: a string-backed
//! [`Error`] carrying an optional source chain, [`Result`], and the
//! `anyhow!` / `bail!` / `ensure!` macros. Like the real crate, `Error`
//! deliberately does **not** implement `std::error::Error` — that is what
//! permits the blanket `From<E: std::error::Error>` conversion powering
//! `?` without colliding with the reflexive `From<T> for T` impl.

use std::fmt;

pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Construct from a concrete error value, preserving it as the source.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Wrap with additional context (outermost message wins in Display).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The innermost source error, if one was preserved.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as _)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait mirroring `anyhow::Context` for `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let _ = std::fs::read_to_string("/definitely/not/a/real/path/442")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.source().is_some());
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(500).unwrap_err().to_string(), "too big");
    }

    #[test]
    fn context_wraps() {
        let e = io_fail().context("loading config").unwrap_err();
        assert!(e.to_string().starts_with("loading config: "));
    }
}
