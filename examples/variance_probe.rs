//! Figure 4 reproduction: per-layer gradient variance during training,
//! without and with last-layer momentum.
//!
//!   cargo run --release --example variance_probe [steps]
//!
//! Expected shape (paper Fig. 4): the lm_head variance dominates under
//! plain column-normalized SGD (a); adding last-layer momentum (SCALE)
//! collapses the head's update-direction variance (b).

use scale_llm::harness::figures::figure4;
use scale_llm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let engine = Engine::new("artifacts")?;
    println!("(a) SGD-col-norm — no momentum anywhere");
    println!("{}", figure4(&engine, "s130m", steps, "sgd_colnorm")?);
    println!("(b) SCALE — momentum on the lm_head only");
    println!("{}", figure4(&engine, "s130m", steps, "scale")?);
    println!("see also: `scale ablate-momentum` for the Theorem 2.1 testbed");
    Ok(())
}
