//! End-to-end driver (DESIGN.md deliverable): the full system on a real
//! small workload, proving all layers compose.
//!
//!   corpus generator -> BPE tokenizer -> shard batcher -> 4-way DDP
//!   gradient computation (L2 fwd/bwd artifact, which embeds the L1
//!   Pallas kernels) -> tree all-reduce -> SCALE update artifact ->
//!   periodic eval + checkpoint -> loss-curve CSV.
//!
//! Trains the `e2e` config (the largest in the tiny family) with SCALE
//! and with Adam as the reference, logging both loss curves. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//!   cargo run --release --example pretrain_e2e [steps] [size]

use scale_llm::coordinator::metrics::ascii_curve;
use scale_llm::coordinator::{TrainOptions, Trainer};
use scale_llm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let size = args.get(2).cloned().unwrap_or_else(|| "e2e".to_string());

    let engine = Engine::new("artifacts")?;
    let info = engine.manifest.size(&size)?.clone();
    println!(
        "end-to-end pretraining: {size} ({:.2}M params, vocab {}, seq {}), {} steps, 4-way DDP, platform {}",
        info.param_count as f64 / 1e6,
        info.vocab,
        info.seq_len,
        steps,
        engine.platform()
    );

    std::fs::create_dir_all("plots").ok();
    let mut results = Vec::new();
    for (opt, lr) in [("scale", 1e-2), ("adam", 2e-3)] {
        println!("\n=== {opt} (lr {lr}) ===");
        let t0 = std::time::Instant::now();
        let opts = TrainOptions {
            size: size.clone(),
            optimizer: opt.into(),
            steps,
            base_lr: lr,
            shards: 4,
            eval_every: (steps / 6).max(1),
            eval_batches: 8,
            log_every: (steps / 12).max(1),
            ..TrainOptions::default()
        };
        let mut tr = Trainer::new(&engine, opts)?;
        let ppl = tr.train()?;
        let wall = t0.elapsed().as_secs_f64();

        // checkpoint round-trip as part of the e2e proof
        let ckpt_path = format!("plots/e2e_{opt}.ckpt");
        tr.checkpoint()?.save(&ckpt_path)?;
        let restored = scale_llm::coordinator::Checkpoint::load(&ckpt_path)?;
        assert_eq!(restored.step as usize, tr.step);

        let csv = format!("plots/e2e_{opt}.csv");
        tr.metrics.write_csv(&csv)?;
        println!("\ntraining-loss curve ({opt}):");
        println!("{}", ascii_curve(&tr.metrics.smoothed_losses(10), 64, 12));
        println!(
            "{opt}: final ppl {ppl:.2} | {:.0} tok/s | state {} KiB | {wall:.0}s wall | curve -> {csv} | ckpt -> {ckpt_path}",
            tr.metrics.tokens_per_sec(),
            tr.state_bytes() / 1024
        );
        results.push((opt, ppl, tr.state_bytes(), tr.metrics.tokens_per_sec()));
    }

    println!("\n=== summary ===");
    for (opt, ppl, state, tps) in &results {
        println!("  {opt:<6} ppl {ppl:>7.2}   state {:>8} KiB   {tps:>6.0} tok/s", state / 1024);
    }
    let (sp, ap) = (results[0].1, results[1].1);
    println!(
        "\nSCALE matches Adam within {:.1}% perplexity using {:.1}% of its optimizer state",
        100.0 * (sp - ap).abs() / ap,
        100.0 * results[0].2 as f64 / results[1].2 as f64
    );
    Ok(())
}
