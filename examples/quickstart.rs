//! Quickstart: train a tiny LLaMA with SCALE for 60 steps.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the minimal API surface: Engine + TrainOptions +
//! Trainer. On the default build this runs on the native CPU executor —
//! no `make artifacts` required; with `--features xla` it executes the
//! PJRT-lowered artifacts instead.

use scale_llm::coordinator::{TrainOptions, Trainer};
use scale_llm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("platform: {}", engine.platform());

    let opts = TrainOptions {
        size: "s60m".into(),
        optimizer: "scale".into(),
        steps: 60,
        base_lr: 1e-2,
        log_every: 10,
        ..TrainOptions::default()
    };
    println!(
        "training {} with SCALE (column-norm everywhere, momentum on the LM head only)",
        opts.size
    );
    let mut tr = Trainer::new(&engine, opts)?;
    let ppl = tr.train()?;

    println!("\nfinal eval perplexity: {ppl:.2}");
    println!(
        "optimizer state: {} KiB vs {} KiB of parameters — the SGD-like footprint the paper claims",
        tr.state_bytes() / 1024,
        4 * engine.manifest.size("s60m")?.param_count / 1024,
    );
    Ok(())
}
