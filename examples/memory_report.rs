//! Appendix B / Table 4 memory accounting — the one exhibit this repo
//! reproduces *exactly*, because it is pure arithmetic over real LLaMA
//! dimensions (bf16, 2 bytes/value).
//!
//!   cargo run --release --example memory_report

use scale_llm::analysis::tables::Table;
use scale_llm::memory::estimator::MemoryModel;
use scale_llm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new("artifacts")?;

    println!("{}", scale_llm::harness::tables::table4(&engine)?);

    // the abstract's headline ratios
    let m7 = MemoryModel::new(engine.manifest.paper_dims["7B"]);
    let m1 = MemoryModel::new(engine.manifest.paper_dims["1B"]);
    let mut t = Table::new(
        "Headline ratios (abstract / §1)",
        &["claim", "paper", "computed"],
    );
    let sgd7 = m7.method("sgd", 0).total_gb();
    let scale7 = m7.method("scale", 0).total_gb();
    let sgd1 = m1.method("sgd", 0).total_gb();
    let scale1 = m1.method("scale", 0).total_gb();
    let adam1 = m1.method("adam", 0).total_gb();
    let muon1 = m1.method("muon", 0).total_gb();
    t.row(vec![
        "SCALE vs SGD overhead @7B".into(),
        "~2%".into(),
        format!("{:.1}%", 100.0 * (scale7 - sgd7) / sgd7),
    ]);
    t.row(vec![
        "SCALE vs SGD overhead @1B".into(),
        "~10%".into(),
        format!("{:.1}%", 100.0 * (scale1 - sgd1) / sgd1),
    ]);
    t.row(vec![
        "SCALE / Adam memory @1B".into(),
        "35%".into(),
        format!("{:.0}%", 100.0 * scale1 / adam1),
    ]);
    t.row(vec![
        "SCALE / Muon memory @1B".into(),
        "52%".into(),
        format!("{:.0}%", 100.0 * scale1 / muon1),
    ]);
    println!("{}", t.render());
    Ok(())
}
