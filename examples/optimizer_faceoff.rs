//! Optimizer faceoff: the paper's Table 5 / Figure 1 shape on one size.
//!
//! Trains the full memory-efficient zoo on s130m and prints the
//! perplexity-vs-memory comparison (paper-scale memory from the
//! Appendix-B estimator, measured perplexity from the tiny runs).
//!
//!   cargo run --release --example optimizer_faceoff [steps]

use scale_llm::analysis::tables::{opt_label, Table};
use scale_llm::harness::{ppl_cell, run_zoo};
use scale_llm::memory::estimator::{measured_state_bytes, MemoryModel};
use scale_llm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let engine = Engine::new("artifacts")?;
    let size = "s130m";
    let opts = [
        "adam", "stable_spam", "muon", "galore", "fira", "apollo",
        "apollo_mini", "swan", "scale",
    ];
    println!("faceoff on {size}, {steps} steps each ({} optimizers)...", opts.len());
    let outs = run_zoo(&engine, &opts, size, steps, false)?;

    let mm = MemoryModel::new(engine.manifest.paper_dims["1B"]);
    let mut t = Table::new(
        "Optimizer faceoff — measured ppl vs memory",
        &["method", "measured ppl", "tiny state KiB", "1B-scale mem (GB)", "tok/s"],
    );
    for r in &outs {
        let rank = if r.spec.optimizer == "apollo_mini" { 1 } else { 256 };
        let mem = mm.method(&r.spec.optimizer, rank).total_gb();
        let kib = measured_state_bytes(&engine.manifest, &r.spec.optimizer, size)? / 1024;
        t.row(vec![
            opt_label(&r.spec.optimizer).to_string(),
            ppl_cell(r.final_ppl),
            format!("{kib}"),
            format!("{mem:.2}"),
            format!("{:.0}", r.tokens_per_sec),
        ]);
    }
    t.footnote("paper shape: SCALE on the Pareto frontier — lowest memory at competitive ppl");
    println!("{}", t.render());
    Ok(())
}
